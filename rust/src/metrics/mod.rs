//! Lightweight metrics registry for the coordinator and CLI.
//!
//! Counters are lock-free atomics; gauges/timings go through a mutex (off
//! the hot path). Snapshots serialize to JSON for logs and reports.
//!
//! Lock poisoning is recovered (the inner guard is taken back): a stage
//! that panics mid-`count`/`time` must not turn every later metrics call —
//! including the crash-path snapshot that reports the failure — into a
//! second panic. The maps only ever hold fully-inserted entries, so the
//! recovered state is safe to keep using.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Lock a metrics map, recovering from poisoning (see module docs).
fn lock_recovering<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared metrics sink.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, &'static AtomicU64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    timings: Mutex<BTreeMap<String, TimingAgg>>,
}

#[derive(Clone, Copy, Default, Debug)]
struct TimingAgg {
    count: u64,
    total_s: f64,
    max_s: f64,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter by `n`.
    pub fn count(&self, name: &str, n: u64) {
        let mut map = lock_recovering(&self.counters);
        let cell = map.entry(name.to_string()).or_insert_with(|| {
            // Counters live for the process lifetime; leak one atomic each.
            Box::leak(Box::new(AtomicU64::new(0)))
        });
        cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Read a counter.
    pub fn counter(&self, name: &str) -> u64 {
        lock_recovering(&self.counters)
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set a gauge to an absolute value.
    pub fn gauge(&self, name: &str, value: f64) {
        lock_recovering(&self.gauges).insert(name.to_string(), value);
    }

    /// Read a gauge (`None` if never set).
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        lock_recovering(&self.gauges).get(name).copied()
    }

    /// Track a gauge as a running maximum (used for high-water queue
    /// depths: the instantaneous depth is racy, the high-water mark is
    /// what backpressure tuning needs).
    pub fn gauge_max(&self, name: &str, value: f64) {
        let mut map = lock_recovering(&self.gauges);
        let entry = map.entry(name.to_string()).or_insert(value);
        if value > *entry {
            *entry = value;
        }
    }

    /// Record one timed operation.
    pub fn time(&self, name: &str, seconds: f64) {
        let mut map = lock_recovering(&self.timings);
        let agg = map.entry(name.to_string()).or_default();
        agg.count += 1;
        agg.total_s += seconds;
        agg.max_s = agg.max_s.max(seconds);
    }

    /// Time a closure and record it.
    pub fn timed<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.time(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Number of recorded samples for a timing (0 if never recorded).
    pub fn timing_count(&self, name: &str) -> u64 {
        lock_recovering(&self.timings).get(name).map(|t| t.count).unwrap_or(0)
    }

    /// Total recorded seconds for a timing (0.0 if never recorded).
    pub fn timing_total(&self, name: &str) -> f64 {
        lock_recovering(&self.timings).get(name).map(|t| t.total_s).unwrap_or(0.0)
    }

    /// Snapshot everything as JSON.
    pub fn snapshot(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in lock_recovering(&self.counters).iter() {
            counters.insert(k.clone(), Json::num(v.load(Ordering::Relaxed) as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in lock_recovering(&self.gauges).iter() {
            gauges.insert(k.clone(), Json::num(*v));
        }
        let mut timings = BTreeMap::new();
        for (k, t) in lock_recovering(&self.timings).iter() {
            timings.insert(
                k.clone(),
                Json::obj(vec![
                    ("count", Json::num(t.count as f64)),
                    ("total_s", Json::num(t.total_s)),
                    ("mean_s", Json::num(if t.count > 0 { t.total_s / t.count as f64 } else { 0.0 })),
                    ("max_s", Json::num(t.max_s)),
                ]),
            );
        }
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("timings".to_string(), Json::Obj(timings)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate_across_threads() {
        let m = Arc::new(Metrics::new());
        let mut joins = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.count("jobs", 1);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(m.counter("jobs"), 8000);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_and_timings() {
        let m = Metrics::new();
        m.gauge("ratio", 42.5);
        m.time("encode", 0.5);
        m.time("encode", 1.5);
        let out = m.timed("t", || 7);
        assert_eq!(out, 7);
        assert_eq!(m.gauge_value("ratio"), Some(42.5));
        assert_eq!(m.gauge_value("missing"), None);
        assert_eq!(m.timing_count("encode"), 2);
        assert_eq!(m.timing_count("missing"), 0);
        assert!((m.timing_total("encode") - 2.0).abs() < 1e-12);
        m.gauge_max("depth", 3.0);
        m.gauge_max("depth", 1.0);
        m.gauge_max("depth", 5.0);
        assert_eq!(m.gauge_value("depth"), Some(5.0));
        let snap = m.snapshot();
        assert_eq!(snap.get("gauges").unwrap().get("ratio").unwrap().as_f64(), Some(42.5));
        let enc = snap.get("timings").unwrap().get("encode").unwrap();
        assert_eq!(enc.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(enc.get("mean_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(enc.get("max_s").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn poisoned_registry_keeps_serving() {
        let m = Arc::new(Metrics::new());
        m.count("jobs", 3);
        m.gauge("depth", 2.0);
        m.time("encode", 0.25);

        // Poison all three maps by panicking while holding each lock —
        // the shape of a stage crashing mid-record.
        let m2 = m.clone();
        let crashed = std::thread::spawn(move || {
            let _guard = m2.counters.lock().unwrap();
            panic!("crash while holding the counters lock");
        });
        assert!(crashed.join().is_err());
        let m2 = m.clone();
        let crashed = std::thread::spawn(move || {
            let _guard = m2.gauges.lock().unwrap();
            panic!("crash while holding the gauges lock");
        });
        assert!(crashed.join().is_err());
        let m2 = m.clone();
        let crashed = std::thread::spawn(move || {
            let _guard = m2.timings.lock().unwrap();
            panic!("crash while holding the timings lock");
        });
        assert!(crashed.join().is_err());

        // Every accessor recovers: reads see pre-crash values, writes
        // keep landing, and the crash-report snapshot still serializes.
        assert_eq!(m.counter("jobs"), 3);
        m.count("jobs", 1);
        assert_eq!(m.counter("jobs"), 4);
        m.gauge("depth", 5.0);
        m.gauge_max("depth", 7.0);
        assert_eq!(m.gauge_value("depth"), Some(7.0));
        m.time("encode", 0.75);
        assert_eq!(m.timing_count("encode"), 2);
        assert!((m.timing_total("encode") - 1.0).abs() < 1e-12);
        let snap = m.snapshot();
        assert_eq!(snap.get("counters").unwrap().get("jobs").unwrap().as_f64(), Some(4.0));
        assert_eq!(snap.get("gauges").unwrap().get("depth").unwrap().as_f64(), Some(7.0));
    }
}

//! Training driver: produces real Adam checkpoints for the experiments.
//!
//! Rust owns the training loop; each step executes an AOT-compiled JAX
//! train-step program (`lm_*_train` / `vit_*_train`) through the PJRT
//! runtime, holding all parameters and Adam moments host-side between
//! steps. Checkpoints captured here are exactly the paper's
//! `P_t = {W_t, O_t}` (Eq. 1): weights + first and second Adam moments.
//!
//! Workload data is synthetic but structured (DESIGN.md §3): the LM corpus
//! is an order-1 Markov chain with a Zipf-ish marginal so the model has
//! real signal to learn; ViT images are class-conditional Gaussian
//! prototypes. Both are deterministic functions of (seed, step).

mod corpus;

pub use corpus::{LmCorpus, VitData};

use crate::checkpoint::{Checkpoint, SnapshotBuilder, SnapshotView};
use crate::runtime::{HostTensor, Manifest, RuntimeHandle};
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Supported workload program families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// GPT-style causal LM (Pythia stand-in).
    Lm,
    /// Small ViT (ViT-L32 stand-in).
    Vit,
}

/// A training session over one workload.
pub struct Trainer {
    rt: RuntimeHandle,
    kind: WorkloadKind,
    /// Program name prefix, e.g. `lm_tiny`.
    prefix: String,
    /// Flat parameter spec (name, shape) from the manifest.
    spec: Vec<(String, Vec<usize>)>,
    params: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
    step: u64,
    // Workload shapes.
    batch: usize,
    seq: usize,
    vocab: usize,
    patches: usize,
    patch_dim: usize,
    classes: usize,
    data_seed: u64,
}

impl Trainer {
    /// Create a trainer for `prefix` (e.g. `"lm_tiny"`, `"vit_tiny"`),
    /// initializing parameters via the workload's `_init` program.
    pub fn new(artifacts_dir: impl AsRef<Path>, prefix: &str, seed: u64) -> Result<Self> {
        let dir: PathBuf = artifacts_dir.as_ref().to_path_buf();
        let rt = RuntimeHandle::spawn(dir.clone())?;
        Self::with_runtime(rt, &dir, prefix, seed)
    }

    /// Same, but reusing an existing runtime handle.
    pub fn with_runtime(
        rt: RuntimeHandle,
        artifacts_dir: &Path,
        prefix: &str,
        seed: u64,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir.join("manifest.json"))?;
        let train_name = format!("{prefix}_train");
        let info = manifest.program(&train_name)?;
        let kind = match info.kind.as_str() {
            "lm_train" => WorkloadKind::Lm,
            "vit_train" => WorkloadKind::Vit,
            other => return Err(Error::config(format!("program kind '{other}' not trainable"))),
        };
        let batch = info.cfg_usize("batch")?;
        let (seq, vocab, patches, patch_dim, classes) = match kind {
            WorkloadKind::Lm => (info.cfg_usize("seq")?, info.cfg_usize("vocab")?, 0, 0, 0),
            WorkloadKind::Vit => (
                0,
                0,
                info.cfg_usize("patches")?,
                info.cfg_usize("patch_dim")?,
                info.cfg_usize("classes")?,
            ),
        };
        let spec = info.params.clone();
        let params = rt.run(&format!("{prefix}_init"), vec![HostTensor::scalar_i32(seed as i32)])?;
        if params.len() != spec.len() {
            return Err(Error::format(format!(
                "init returned {} tensors, manifest lists {}",
                params.len(),
                spec.len()
            )));
        }
        let m: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
        let v = m.clone();
        Ok(Self {
            rt,
            kind,
            prefix: prefix.to_string(),
            spec,
            params,
            m,
            v,
            step: 0,
            batch,
            seq,
            vocab,
            patches,
            patch_dim,
            classes,
            data_seed: seed ^ 0xdada,
        })
    }

    /// Current training step.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Workload kind.
    pub fn kind(&self) -> WorkloadKind {
        self.kind
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Runtime handle (shared with codecs and evaluators).
    pub fn runtime(&self) -> &RuntimeHandle {
        &self.rt
    }

    /// Run one optimizer step on the next synthetic batch; returns loss.
    pub fn step_once(&mut self) -> Result<f32> {
        self.step += 1;
        let n = self.spec.len();
        let mut args = Vec::with_capacity(3 * n + 3);
        args.extend(self.params.iter().cloned());
        args.extend(self.m.iter().cloned());
        args.extend(self.v.iter().cloned());
        args.push(HostTensor::scalar_f32(self.step as f32));
        match self.kind {
            WorkloadKind::Lm => {
                let toks = LmCorpus::new(self.vocab, self.data_seed)
                    .batch(self.step, self.batch, self.seq + 1);
                args.push(HostTensor::i32(vec![self.batch, self.seq + 1], toks)?);
            }
            WorkloadKind::Vit => {
                let (imgs, labels) = VitData::new(self.patches, self.patch_dim, self.classes, self.data_seed)
                    .batch(self.step, self.batch);
                args.push(HostTensor::f32(
                    vec![self.batch, self.patches, self.patch_dim],
                    imgs,
                )?);
                args.push(HostTensor::i32(vec![self.batch], labels)?);
            }
        }
        let mut out = self.rt.run(&format!("{}_train", self.prefix), args)?;
        if out.len() != 3 * n + 1 {
            return Err(Error::Xla(format!(
                "train program returned {} outputs, want {}",
                out.len(),
                3 * n + 1
            )));
        }
        let loss = out.pop().unwrap().f32s()?[0];
        self.v = out.split_off(2 * n);
        self.m = out.split_off(n);
        self.params = out;
        Ok(loss)
    }

    /// Run `steps` steps, invoking `on_step(step, loss)` after each.
    pub fn train(&mut self, steps: u64, mut on_step: impl FnMut(u64, f32)) -> Result<()> {
        for _ in 0..steps {
            let loss = self.step_once()?;
            on_step(self.step, loss);
        }
        Ok(())
    }

    /// Held-out loss on a deterministic eval batch (LM only).
    pub fn eval_loss(&self) -> Result<f32> {
        if self.kind != WorkloadKind::Lm {
            return Err(Error::config("eval_loss only for LM workloads"));
        }
        // Eval stream lives far from the training stream.
        let toks = LmCorpus::new(self.vocab, self.data_seed ^ 0xeeee)
            .batch(u64::MAX / 2, self.batch, self.seq + 1);
        let mut args = self.params.clone();
        args.push(HostTensor::i32(vec![self.batch, self.seq + 1], toks)?);
        let out = self.rt.run(&format!("{}_eval", self.prefix), args)?;
        Ok(out[0].f32s()?[0])
    }

    /// Capture the current `P_t = {W_t, O_t}`.
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = Checkpoint { step: self.step, ..Default::default() };
        for (i, (name, shape)) in self.spec.iter().enumerate() {
            ck.weights
                .insert(name.clone(), Tensor::new(shape.clone(), self.params[i].f32s()?.to_vec())?);
            ck.exp_avg
                .insert(name.clone(), Tensor::new(shape.clone(), self.m[i].f32s()?.to_vec())?);
            ck.exp_avg_sq
                .insert(name.clone(), Tensor::new(shape.clone(), self.v[i].f32s()?.to_vec())?);
        }
        Ok(ck)
    }

    /// Phase-1 of a two-phase capture: freeze the current `P_t` into an
    /// owned [`SnapshotView`] in O(memcpy) — no encode, no disk. The view
    /// rebuilds the exact checkpoint [`Trainer::checkpoint`] would have
    /// produced at this step (byte-determinism contract), so handing it to
    /// [`crate::coordinator::CaptureHandle::capture`] compresses to
    /// identical bytes while training continues.
    pub fn snapshot(&self) -> Result<SnapshotView> {
        let mut b = SnapshotBuilder::new(self.step);
        for (i, (name, shape)) in self.spec.iter().enumerate() {
            b.push(
                name.clone(),
                shape.clone(),
                self.params[i].f32s()?,
                self.m[i].f32s()?,
                self.v[i].f32s()?,
            )?;
        }
        b.finish()
    }

    /// Restore state from a checkpoint (the resume-from-compressed path).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        for (i, (name, shape)) in self.spec.iter().enumerate() {
            let w = ck
                .weights
                .get(name)
                .ok_or_else(|| Error::format(format!("checkpoint missing tensor '{name}'")))?;
            if w.shape() != shape.as_slice() {
                return Err(Error::shape(format!(
                    "tensor '{name}' shape {:?} != expected {shape:?}",
                    w.shape()
                )));
            }
            let m = ck.exp_avg.get(name).ok_or_else(|| Error::format("missing exp_avg"))?;
            let v = ck.exp_avg_sq.get(name).ok_or_else(|| Error::format("missing exp_avg_sq"))?;
            self.params[i] = HostTensor::f32(shape.clone(), w.data().to_vec())?;
            self.m[i] = HostTensor::f32(shape.clone(), m.data().to_vec())?;
            self.v[i] = HostTensor::f32(shape.clone(), v.data().to_vec())?;
        }
        self.step = ck.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        arts().join("manifest.json").exists()
    }

    #[test]
    fn lm_trains_and_checkpoints() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut tr = Trainer::new(arts(), "lm_tiny", 7).unwrap();
        assert_eq!(tr.kind(), WorkloadKind::Lm);
        assert!(tr.param_count() > 100_000);
        let mut losses = Vec::new();
        tr.train(8, |_s, l| losses.push(l)).unwrap();
        assert_eq!(losses.len(), 8);
        assert!(losses.iter().all(|l| l.is_finite()));
        // Early loss should be near ln(vocab) and declining.
        assert!(losses[0] > 4.0 && losses[0] < 8.0, "losses={losses:?}");
        assert!(losses[7] < losses[0], "losses={losses:?}");

        let ck = tr.checkpoint().unwrap();
        assert_eq!(ck.step, 8);
        assert_eq!(ck.param_count(), tr.param_count());
        // Second moment is non-negative everywhere.
        for e in ck.exp_avg_sq.iter() {
            assert!(e.tensor.data().iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn restore_resumes_identically() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut tr = Trainer::new(arts(), "lm_tiny", 3).unwrap();
        tr.train(4, |_, _| {}).unwrap();
        let ck = tr.checkpoint().unwrap();
        let mut l_a = Vec::new();
        tr.train(3, |_, l| l_a.push(l)).unwrap();

        // Fresh trainer restored from the checkpoint must replay the same
        // losses (same data stream, same state).
        let mut tr2 = Trainer::new(arts(), "lm_tiny", 3).unwrap();
        tr2.restore(&ck).unwrap();
        assert_eq!(tr2.step(), 4);
        let mut l_b = Vec::new();
        tr2.train(3, |_, l| l_b.push(l)).unwrap();
        assert_eq!(l_a, l_b);
    }

    #[test]
    fn vit_trains() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut tr = Trainer::new(arts(), "vit_tiny", 1).unwrap();
        assert_eq!(tr.kind(), WorkloadKind::Vit);
        let mut losses = Vec::new();
        tr.train(6, |_, l| losses.push(l)).unwrap();
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(losses[5] < losses[0] + 0.1, "losses={losses:?}");
        assert!(tr.eval_loss().is_err(), "eval only for LM");
    }

    #[test]
    fn eval_loss_changes_with_training() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut tr = Trainer::new(arts(), "lm_tiny", 5).unwrap();
        let e0 = tr.eval_loss().unwrap();
        tr.train(10, |_, _| {}).unwrap();
        let e1 = tr.eval_loss().unwrap();
        assert_ne!(e0, e1);
        assert!(e1 < e0 + 0.5);
    }

    #[test]
    fn unknown_prefix_fails() {
        if !have_artifacts() {
            return;
        }
        assert!(Trainer::new(arts(), "nope", 0).is_err());
    }
}

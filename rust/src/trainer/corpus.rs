//! Synthetic but structured workload data (deterministic in (seed, step)).

use crate::util::rng::Pcg64;

/// Order-1 Markov token stream with a skewed marginal.
///
/// Each vocabulary state has 4 "preferred" successors (sampled once from a
/// Zipf marginal); with probability 0.75 the next token is one of them,
/// otherwise it is drawn from the global Zipf marginal. This gives the LM
/// real predictable structure (bigram mutual information) so training
/// reduces loss and checkpoints evolve like real training runs.
pub struct LmCorpus {
    vocab: usize,
    seed: u64,
    /// 4 preferred successors per state.
    succ: Vec<u32>,
}

impl LmCorpus {
    /// Build the transition structure for `vocab` tokens.
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0xc0);
        let mut succ = Vec::with_capacity(vocab * 4);
        for _ in 0..vocab {
            for _ in 0..4 {
                succ.push(rng.zipf(vocab as u64, 1.1) as u32);
            }
        }
        Self { vocab, seed, succ }
    }

    /// Deterministic batch for training step `step`: `batch × len` i32.
    pub fn batch(&self, step: u64, batch: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * len);
        for row in 0..batch {
            let mut rng = Pcg64::new(self.seed ^ step, row as u64);
            let mut tok = rng.zipf(self.vocab as u64, 1.1) as usize;
            for _ in 0..len {
                out.push(tok as i32);
                tok = if rng.f64() < 0.75 {
                    self.succ[tok * 4 + rng.below_usize(4)] as usize
                } else {
                    rng.zipf(self.vocab as u64, 1.1) as usize
                };
            }
        }
        out
    }
}

/// Class-conditional Gaussian "images", pre-patchified.
///
/// Each class has a fixed prototype in patch space; a sample is
/// `prototype[label] + 0.5 · noise`. Linearly separable enough that the
/// tiny ViT's loss falls quickly, with enough noise that Adam moments stay
/// busy.
pub struct VitData {
    patches: usize,
    patch_dim: usize,
    classes: usize,
    seed: u64,
    protos: Vec<f32>,
}

impl VitData {
    /// Build class prototypes.
    pub fn new(patches: usize, patch_dim: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0x717);
        let protos: Vec<f32> =
            (0..classes * patches * patch_dim).map(|_| rng.normal_f32()).collect();
        Self { patches, patch_dim, classes, seed, protos }
    }

    /// Deterministic batch for `step`: (images `B×P×D` flat, labels `B`).
    pub fn batch(&self, step: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let img_len = self.patches * self.patch_dim;
        let mut images = Vec::with_capacity(batch * img_len);
        let mut labels = Vec::with_capacity(batch);
        for row in 0..batch {
            let mut rng = Pcg64::new(self.seed ^ step, 0x9000 + row as u64);
            let label = rng.below_usize(self.classes);
            labels.push(label as i32);
            let proto = &self.protos[label * img_len..(label + 1) * img_len];
            for &p in proto {
                images.push(p + 0.5 * rng.normal_f32());
            }
        }
        (images, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_batches_deterministic_and_step_dependent() {
        let c = LmCorpus::new(128, 42);
        let a = c.batch(5, 4, 33);
        let b = c.batch(5, 4, 33);
        assert_eq!(a, b);
        assert_ne!(a, c.batch(6, 4, 33));
        assert_eq!(a.len(), 4 * 33);
        assert!(a.iter().all(|&t| t >= 0 && t < 128));
    }

    #[test]
    fn lm_has_bigram_structure() {
        // The same (state) should frequently lead to its preferred
        // successors: measure repeat-bigram rate vs a uniform stream.
        let c = LmCorpus::new(64, 1);
        let toks = c.batch(1, 1, 4000);
        let mut seen = std::collections::HashMap::new();
        let mut hits = 0usize;
        for w in toks.windows(2) {
            let e = seen.entry(w[0]).or_insert_with(std::collections::HashSet::new);
            if e.contains(&w[1]) {
                hits += 1;
            }
            e.insert(w[1]);
        }
        // With 4 preferred successors per state, repeats dominate quickly.
        assert!(hits > toks.len() / 2, "hits={hits}");
    }

    #[test]
    fn lm_marginal_is_skewed() {
        let c = LmCorpus::new(256, 9);
        let toks = c.batch(3, 8, 500);
        let low: usize = toks.iter().filter(|&&t| t < 32).count();
        assert!(low * 2 > toks.len(), "low-token share {}/{}", low, toks.len());
    }

    #[test]
    fn vit_batches_deterministic_and_classy() {
        let d = VitData::new(8, 12, 4, 7);
        let (img_a, lab_a) = d.batch(2, 16);
        let (img_b, lab_b) = d.batch(2, 16);
        assert_eq!(img_a, img_b);
        assert_eq!(lab_a, lab_b);
        assert_eq!(img_a.len(), 16 * 8 * 12);
        assert!(lab_a.iter().all(|&l| l >= 0 && l < 4));
        // Same-class rows are closer than cross-class rows on average.
        let img_len = 8 * 12;
        let dist = |i: usize, j: usize| -> f32 {
            (0..img_len)
                .map(|k| (img_a[i * img_len + k] - img_a[j * img_len + k]).powi(2))
                .sum()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..16 {
            for j in (i + 1)..16 {
                if lab_a[i] == lab_a[j] {
                    same.push(dist(i, j));
                } else {
                    diff.push(dist(i, j));
                }
            }
        }
        if !same.is_empty() && !diff.is_empty() {
            let ms: f32 = same.iter().sum::<f32>() / same.len() as f32;
            let md: f32 = diff.iter().sum::<f32>() / diff.len() as f32;
            assert!(ms < md, "same-class {ms} vs cross-class {md}");
        }
    }
}

//! Command-line interface (the launcher).
//!
//! ```text
//! cpcm train      --workload lm_tiny --steps 300 --ckpt-every 50 \
//!                 --out runs/demo [--compress] [--mode lstm] [--backend native]
//!                 [--lanes N] [--queue-depth N] [--shard-bytes N] [--shard-threads N]
//!                 [--adaptive-bits] [--snapshot-cadence N]   # two-phase capture stress knob
//! cpcm compress   --ckpts runs/demo/raw --out runs/demo/cpcm [--mode ...]
//!                 [--lanes N] [--queue-depth N] [--shard-bytes N] [--shard-threads N]
//!                 [--adaptive-bits]   # per-fragment width allocation (format 5)
//! cpcm decompress --cpcm runs/demo/cpcm --step 100 --out ck.bin [--backend ...]
//!                 [--shard-threads N]   # 0 = auto; 1 pins the strict one-shard RSS bound
//! cpcm verify     --ckpts runs/demo/raw --cpcm runs/demo/cpcm
//! cpcm scrub      --cpcm runs/demo/cpcm [--repair]
//! cpcm gc         --cpcm runs/demo/cpcm --retain-last N [--retain-every M]
//! cpcm compact    --cpcm runs/demo/cpcm --step S [--backend ...]
//! cpcm info       --file runs/demo/cpcm/ckpt_0000000100.cpcm
//! cpcm config     --write cpcm.json          # dump the default config
//! cpcm serve      --root runs/fleet [--addr 127.0.0.1:7070] [--max-tenants N]
//!                 [--quota-bytes N] [--max-conns N] [--max-body-bytes N]
//!                 [--backend ...] [--queue-depth N] [--keyframe-every N]
//! ```
//!
//! Flags mirror [`crate::config::ExperimentConfig`]; `--config file.json`
//! loads a base config that individual flags then override. Chain
//! lifecycle knobs: `--keyframe-every N` (alias `--keyframe-interval`)
//! bounds restore depth at write time, `--retain-last N` /
//! `--retain-every M` garbage-collect old steps as training goes, and
//! `--compact-depth D` rebases any chain deeper than D onto a lossless
//! keyframe.
//!
//! `scrub` audits a container directory (framing, body CRCs,
//! manifest/header agreement, chain restorability, litter) and exits
//! nonzero when anything is off; `--repair` quarantines the damage and
//! rewrites a consistent manifest instead.
//!
//! `decompress` restores through the directory's `manifest.json` when one
//! is present (decoding only the requested step's reference ancestry —
//! streamed shard-by-shard to disk when the whole ancestry is format 3,
//! so restore memory stays bounded by the shard budget) and falls back to
//! a full chain decode for manifest-less directories.

mod args;

use crate::checkpoint::Store;
use crate::codec::ContextMode;
use crate::config::{BackendKind, ExperimentConfig};
use crate::container::Container;
use crate::coordinator::{
    compact_step, decode_chain, gc_dir, repair_dir, restore_step_to_file_with, scrub_dir,
    ChainManifest, Coordinator, CoordinatorConfig, RetentionPolicy,
};
use crate::lstm::Backend;
use crate::runtime::RuntimeHandle;
use crate::trainer::Trainer;
use crate::{Error, Result};
use args::Args;
use std::path::PathBuf;

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "train" => cmd_train(args),
        "compress" => cmd_compress(args),
        "decompress" => cmd_decompress(args),
        "verify" => cmd_verify(args),
        "scrub" => cmd_scrub(args),
        "gc" => cmd_gc(args),
        "compact" => cmd_compact(args),
        "info" => cmd_info(args),
        "config" => cmd_config(args),
        "serve" => cmd_serve(args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(Error::config(format!("unknown command '{other}' (try `cpcm help`)"))),
    }
}

fn print_usage() {
    println!(
        "cpcm — prediction/context-modeling checkpoint compression\n\
         commands: train, compress, decompress, verify, scrub, gc, compact, info, config, serve, help\n\
         run `cpcm <cmd> --help`-style flags are listed in the module docs"
    );
}

/// Build an ExperimentConfig from `--config` + flag overrides.
fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get("workload") {
        cfg.workload = v.to_string();
    }
    if let Some(v) = args.get("steps") {
        cfg.steps = parse_num(v, "steps")?;
    }
    if let Some(v) = args.get("ckpt-every") {
        cfg.ckpt_every = parse_num(v, "ckpt-every")?;
    }
    // Two-phase capture cadence (0 = follow ckpt-every): freeze a
    // snapshot into the pipeline every N steps, decoupled from raw saves.
    if let Some(v) = args.parsed::<u64>("snapshot-cadence")? {
        cfg.snapshot_cadence = v;
    }
    if let Some(v) = args.get("step-size") {
        cfg.step_size = parse_num(v, "step-size")?;
    }
    if let Some(v) = args.get("keyframe-every").or_else(|| args.get("keyframe-interval")) {
        cfg.keyframe_every = parse_num(v, "keyframe-every")?;
    }
    if let Some(v) = args.parsed::<u64>("retain-last")? {
        cfg.retain_last = v;
    }
    if let Some(v) = args.parsed::<u64>("retain-every")? {
        cfg.retain_every = v;
    }
    if let Some(v) = args.parsed::<u64>("compact-depth")? {
        cfg.compact_depth = v;
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = parse_num(v, "seed")?;
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.to_string();
    }
    if let Some(v) = args.get("out") {
        cfg.out_dir = v.to_string();
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = BackendKind::parse(v)?;
    }
    if args.flag("verify") {
        cfg.verify = true;
    }
    if let Some(v) = args.get("mode") {
        cfg.codec.mode = match v {
            "lstm" => ContextMode::Lstm,
            "zero-context" | "zero_context" => ContextMode::ZeroContext,
            "mixed" => ContextMode::Mixed,
            "order0" => ContextMode::Order0,
            other => return Err(Error::config(format!("unknown mode '{other}'"))),
        };
    }
    if let Some(v) = args.get("bits") {
        cfg.codec.bits = parse_num::<u64>(v, "bits")? as u8;
    }
    if let Some(v) = args.get("window") {
        cfg.codec.window = parse_num::<u64>(v, "window")? as usize;
    }
    if let Some(v) = args.get("hidden") {
        cfg.codec.hidden = parse_num::<u64>(v, "hidden")? as usize;
        cfg.codec.embed = cfg.codec.hidden;
    }
    // Coding lanes per parameter set (format-2 parallelism); 0 = auto.
    if let Some(v) = args.parsed::<u64>("lanes")? {
        cfg.codec.lanes = v as usize;
    }
    // Streaming shard budget in raw value bytes (0 = unsharded format 2;
    // >0 writes format-3 containers with bounded encoder memory).
    if let Some(v) = args.parsed::<u64>("shard-bytes")? {
        cfg.codec.shard_bytes = v as usize;
    }
    // Shard-scheduler parallelism for format-3 paths (0 = auto, the
    // available hardware threads); also bounds the streaming look-ahead.
    if let Some(v) = args.parsed::<u64>("shard-threads")? {
        cfg.codec.shard_threads = v as usize;
    }
    // Coordinator queue depth (submission + stage queues).
    if let Some(v) = args.parsed::<u64>("queue-depth")? {
        cfg.queue_depth = v as usize;
    }
    // Per-fragment dynamic bit allocation (format 5); `--bits` stays the
    // default width and the hard ceiling.
    if args.flag("adaptive-bits") {
        cfg.codec.adaptive_bits = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn make_backend(kind: BackendKind, artifacts: &str) -> Result<Backend> {
    Ok(match kind {
        BackendKind::Native => Backend::Native,
        BackendKind::Pjrt => Backend::Pjrt(RuntimeHandle::spawn(artifacts)?),
    })
}

/// `cpcm train` — run the workload, optionally compressing checkpoints
/// through the coordinator as they are produced.
fn cmd_train(args: Args) -> Result<()> {
    let cfg = experiment_config(&args)?;
    let compress = args.flag("compress");
    let out = PathBuf::from(&cfg.out_dir);
    let raw_store = Store::open(out.join("raw"))?;

    let mut trainer = Trainer::new(&cfg.artifacts_dir, &cfg.workload, cfg.seed)?;
    println!(
        "training {} ({} params) for {} steps, checkpoint every {}",
        cfg.workload,
        trainer.param_count(),
        cfg.steps,
        cfg.ckpt_every
    );

    // Compression runs behind the zero-stall capture handle: each
    // snapshot is frozen in O(memcpy) and handed off; the forwarder
    // thread absorbs the pipeline's backpressure.
    let capture = if compress {
        let mut ccfg = CoordinatorConfig::new(
            cfg.codec.clone(),
            make_backend(cfg.backend, &cfg.artifacts_dir)?,
            out.join("cpcm"),
        );
        ccfg.step_size = cfg.step_size;
        ccfg.keyframe_every = cfg.keyframe_every;
        ccfg.verify = cfg.verify;
        ccfg.queue_depth = cfg.queue_depth;
        ccfg.retain_last = cfg.retain_last;
        ccfg.retain_every = cfg.retain_every;
        ccfg.compact_depth = cfg.compact_depth;
        Some(Coordinator::start(ccfg)?.into_capture_handle()?)
    } else {
        None
    };

    let mut loss_log = String::from("step,loss\n");
    let ckpt_every = cfg.ckpt_every;
    let snap_every =
        if cfg.snapshot_cadence > 0 { cfg.snapshot_cadence } else { cfg.ckpt_every };
    let total = cfg.steps;
    let mut last_loss = f32::NAN;
    for _ in 0..total {
        let loss = trainer.step_once()?;
        last_loss = loss;
        let step = trainer.step();
        loss_log.push_str(&format!("{step},{loss}\n"));
        if step % 20 == 0 || step == total {
            println!("step {step:>6}  loss {loss:.4}");
        }
        if step % ckpt_every == 0 {
            raw_store.save(&trainer.checkpoint()?)?;
        }
        if let Some(handle) = &capture {
            if step % snap_every == 0 {
                handle.capture(trainer.snapshot()?)?;
            }
        }
    }
    std::fs::write(out.join("loss.csv"), loss_log)?;
    println!("final loss {last_loss:.4}; loss curve → {}", out.join("loss.csv").display());

    if let Some(handle) = capture {
        let metrics = handle.metrics();
        let results = handle.finish()?;
        let mut report = String::from("step,ref_step,raw_bytes,cpcm_bytes,ratio\n");
        for r in &results {
            println!(
                "ckpt {:>8}  ref {:>8}  {:>10} B  ratio {:>7.2}  ({:.2}s)",
                r.step,
                r.ref_step.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
                r.bytes,
                r.stats.ratio(),
                r.stats.encode_seconds,
            );
            report.push_str(&format!(
                "{},{},{},{},{}\n",
                r.step,
                r.ref_step.map(|s| s.to_string()).unwrap_or_default(),
                r.stats.raw_bytes,
                r.bytes,
                r.stats.ratio()
            ));
        }
        std::fs::write(out.join("compression.csv"), report)?;
        // Zero-stall evidence: what training actually paid per snapshot
        // vs what the pipeline spent encoding it.
        let stalls = metrics.timing_count("stall_seconds");
        let encodes = metrics.timing_count("stage_entropy");
        if stalls > 0 && encodes > 0 {
            println!(
                "snapshot stall {:.4}s mean over {stalls} captures (encode {:.4}s mean)",
                metrics.timing_total("stall_seconds") / stalls as f64,
                metrics.timing_total("stage_entropy") / encodes as f64,
            );
        }
    }
    // Run provenance.
    std::fs::write(out.join("config.json"), cfg.to_json().to_string_pretty())?;
    Ok(())
}

/// `cpcm compress` — compress an existing raw checkpoint directory.
fn cmd_compress(args: Args) -> Result<()> {
    let cfg = experiment_config(&args)?;
    let ckpts = args.req("ckpts")?;
    let out = args.get("out").unwrap_or("cpcm_out");
    let store = Store::open(ckpts)?;
    let steps = store.steps()?;
    if steps.is_empty() {
        return Err(Error::config(format!("no checkpoints in {ckpts}")));
    }
    let mut ccfg = CoordinatorConfig::new(
        cfg.codec.clone(),
        make_backend(cfg.backend, &cfg.artifacts_dir)?,
        out,
    );
    ccfg.step_size = cfg.step_size;
    ccfg.keyframe_every = cfg.keyframe_every;
    ccfg.verify = cfg.verify;
    ccfg.queue_depth = cfg.queue_depth;
    ccfg.retain_last = cfg.retain_last;
    ccfg.retain_every = cfg.retain_every;
    ccfg.compact_depth = cfg.compact_depth;
    let coord = Coordinator::start(ccfg)?;
    for step in &steps {
        coord.submit(store.load(*step)?)?;
    }
    let results = coord.finish()?;
    let mut total_raw = 0usize;
    let mut total_out = 0usize;
    for r in &results {
        total_raw += r.stats.raw_bytes;
        total_out += r.bytes;
        println!("ckpt {:>8}  {:>10} B  ratio {:>7.2}", r.step, r.bytes, r.stats.ratio());
    }
    println!(
        "total: {} checkpoints, {:.1} MB → {:.2} MB, overall ratio {:.2}",
        results.len(),
        total_raw as f64 / 1e6,
        total_out as f64 / 1e6,
        total_raw as f64 / total_out as f64
    );
    Ok(())
}

/// `cpcm decompress` — restore the checkpoint at `--step` and write the
/// raw checkpoint file. With a `manifest.json` in the container directory
/// only the step's reference ancestry is decoded, and all-format-3
/// ancestries restore **streaming**: shard-by-shard to disk with
/// references read by range, so recovery works for checkpoints larger
/// than RAM ([`crate::coordinator::restore_step_to_file_with`]).
/// `--shard-threads` bounds the restore scheduler's width and therefore
/// its peak RSS (~O(width · shard); 0 = auto, 1 = the strict one-shard
/// bound). Manifest-less directories decode the chain front-to-back up
/// to the step.
fn cmd_decompress(args: Args) -> Result<()> {
    let cpcm = args.req("cpcm")?;
    let step: u64 = parse_num(args.req("step")?, "step")?;
    let out = args.req("out")?;
    let backend_kind = BackendKind::parse(args.get("backend").unwrap_or("native"))?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    // Shard-scheduler width for the streaming restore (0 = auto); pass 1
    // on memory-limited hosts to pin peak RSS at the strict one-shard
    // bound.
    let shard_threads = args.parsed::<u64>("shard-threads")?.unwrap_or(0) as usize;
    if shard_threads > crate::codec::MAX_SHARD_THREADS {
        return Err(Error::config(format!(
            "--shard-threads must be 0 (auto) or 1..={}",
            crate::codec::MAX_SHARD_THREADS
        )));
    }
    let backend = make_backend(backend_kind, artifacts)?;
    let dir = std::path::Path::new(cpcm);
    if ChainManifest::exists_in(dir) {
        restore_step_to_file_with(dir, &backend, step, std::path::Path::new(out), shard_threads)?;
        let params: usize =
            crate::checkpoint::CheckpointFileReader::open(out)?.counts().iter().sum();
        println!("wrote step {step} ({params} params) to {out}");
    } else {
        let ck = decode_chain(dir, &backend, Some(step))?
            .into_iter()
            .find(|c| c.step == step)
            .ok_or_else(|| Error::config(format!("step {step} not found in {cpcm}")))?;
        std::fs::write(out, ck.to_bytes())?;
        println!("wrote step {step} ({} params) to {out}", ck.param_count());
    }
    Ok(())
}

/// `cpcm verify` — decode every container and compare against the raw
/// store within quantization tolerance; also re-checks CRCs.
fn cmd_verify(args: Args) -> Result<()> {
    let ckpts = args.req("ckpts")?;
    let cpcm = args.req("cpcm")?;
    let backend_kind = BackendKind::parse(args.get("backend").unwrap_or("native"))?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    let backend = make_backend(backend_kind, artifacts)?;
    let store = Store::open(ckpts)?;
    let decoded = decode_chain(std::path::Path::new(cpcm), &backend, None)?;
    let mut worst: f64 = 0.0;
    for ck in &decoded {
        let raw = store.load(ck.step)?;
        if !raw.same_layout(ck) {
            return Err(Error::codec(format!("layout mismatch at step {}", ck.step)));
        }
        let mut max_err: f64 = 0.0;
        for (a, b) in ck.weights.iter().zip(raw.weights.iter()) {
            for (&x, &y) in a.tensor.data().iter().zip(b.tensor.data()) {
                max_err = max_err.max((x as f64 - y as f64).abs());
            }
        }
        worst = worst.max(max_err);
        println!("step {:>8}: max |w_dec − w_raw| = {max_err:.3e}", ck.step);
    }
    println!("verified {} checkpoints; worst weight error {worst:.3e}", decoded.len());
    Ok(())
}

/// `cpcm scrub` — audit a container directory against its manifest:
/// framing, full-body CRCs, header/manifest agreement, per-step chain
/// restorability, stale temps and orphans. Read-only by default and
/// errors when anything is inconsistent (so scripts and CI notice);
/// `--repair` quarantines corrupt steps and their dependent suffix,
/// removes the litter, and rewrites a consistent manifest.
fn cmd_scrub(args: Args) -> Result<()> {
    let dir = std::path::Path::new(args.req("cpcm")?);
    let report = scrub_dir(dir)?;
    println!("scrub {}: {}", dir.display(), report.summary());
    for f in report.corrupt.iter().chain(report.missing.iter()) {
        println!("  step {:>8}  {}: {}", f.step, f.file, f.error);
    }
    for step in &report.unrestorable {
        println!("  step {step:>8}  intact but unrestorable (broken ancestry)");
    }
    if report.consistent() {
        println!("consistent: all {} live steps restorable", report.restorable.len());
        return Ok(());
    }
    if !args.flag("repair") {
        return Err(Error::format(format!(
            "{} is inconsistent (rerun with --repair to quarantine the damage)",
            dir.display()
        )));
    }
    let repair = repair_dir(dir)?;
    for (step, kept) in &repair.quarantined {
        match kept {
            Some(file) => println!("  quarantined step {step} → {file}"),
            None => println!("  quarantined step {step} (container already missing)"),
        }
    }
    let after = scrub_dir(dir)?;
    if !after.consistent() {
        return Err(Error::format(format!(
            "repair left {} inconsistent: {}",
            dir.display(),
            after.summary()
        )));
    }
    println!("repaired: {} live steps remain, all restorable", after.restorable.len());
    Ok(())
}

/// `cpcm gc` — apply a retention policy to a directory offline (the
/// same pass the coordinator runs inline with `--retain-last` /
/// `--retain-every`). Ancestors of retained steps are never collected.
fn cmd_gc(args: Args) -> Result<()> {
    let dir = std::path::Path::new(args.req("cpcm")?);
    let policy = RetentionPolicy {
        keep_last: args.parsed::<u64>("retain-last")?.unwrap_or(0),
        keep_every: args.parsed::<u64>("retain-every")?.unwrap_or(0),
    };
    if !policy.enabled() {
        return Err(Error::config("gc needs --retain-last N and/or --retain-every M"));
    }
    let report = gc_dir(dir, &policy)?;
    println!(
        "gc {}: removed {} steps, {} remain",
        dir.display(),
        report.removed.len(),
        report.kept.len()
    );
    Ok(())
}

/// `cpcm compact` — rebase the chain ending at `--step` onto a lossless
/// keyframe so later restores of it (and its descendants) decode one
/// container instead of the whole ancestry.
fn cmd_compact(args: Args) -> Result<()> {
    let dir = std::path::Path::new(args.req("cpcm")?);
    let step: u64 = parse_num(args.req("step")?, "step")?;
    let backend_kind = BackendKind::parse(args.get("backend").unwrap_or("native"))?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    let backend = make_backend(backend_kind, artifacts)?;
    let report = compact_step(dir, &backend, step)?;
    if report.old_depth == 1 {
        println!("step {step} is already a keyframe ({})", report.file);
    } else {
        println!(
            "compacted step {step}: depth {} → 1, keyframe {} ({} bytes)",
            report.old_depth, report.file, report.bytes
        );
    }
    Ok(())
}

/// `cpcm info` — pretty-print a container header.
fn cmd_info(args: Args) -> Result<()> {
    let file = args.req("file")?;
    let bytes = std::fs::read(file)?;
    let container = Container::from_bytes(&bytes)?;
    println!("{}", container.header.to_string_pretty());
    println!("blobs: {}", container.blobs.len());
    println!("total size: {} bytes", bytes.len());
    Ok(())
}

/// `cpcm config` — write the default experiment config as JSON.
fn cmd_config(args: Args) -> Result<()> {
    let cfg = ExperimentConfig::default();
    let text = cfg.to_json().to_string_pretty();
    match args.get("write") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `cpcm serve` — run the multi-tenant checkpoint daemon
/// ([`crate::server`]). `--root` is the serve root (tenant chains under
/// `tenants/`, the content-addressed dedup store under `objects/`);
/// codec, backend and pipeline flags are shared with `compress`.
fn cmd_serve(args: Args) -> Result<()> {
    let cfg = experiment_config(&args)?;
    let root = args.req("root")?;
    let mut scfg = crate::server::ServeConfig::new(root);
    scfg.codec = cfg.codec.clone();
    scfg.queue_depth = cfg.queue_depth;
    scfg.keyframe_every = cfg.keyframe_every;
    if let Some(v) = args.get("addr") {
        scfg.addr = v.to_string();
    }
    if let Some(v) = args.parsed::<u64>("max-tenants")? {
        scfg.max_tenants = v as usize;
    }
    if let Some(v) = args.parsed::<u64>("quota-bytes")? {
        scfg.quota_bytes = v;
    }
    if let Some(v) = args.parsed::<u64>("max-conns")? {
        scfg.max_conns = v as usize;
    }
    if let Some(v) = args.parsed::<u64>("max-body-bytes")? {
        scfg.max_body_bytes = v as usize;
    }
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let server = crate::server::Server::bind(scfg, backend)?;
    println!("cpcm serve listening on {}", server.local_addr()?);
    server.run()
}

fn parse_num<T: std::str::FromStr>(s: &str, what: &str) -> Result<T> {
    s.parse().map_err(|_| Error::config(format!("invalid --{what}: '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn help_and_empty_ok() {
        assert!(run(vec![]).is_ok());
        assert!(run(vec!["help".into()]).is_ok());
    }

    #[test]
    fn experiment_config_overrides() {
        let args = Args::parse(&[
            "--workload".into(),
            "vit_tiny".into(),
            "--steps".into(),
            "10".into(),
            "--mode".into(),
            "order0".into(),
            "--bits".into(),
            "2".into(),
            "--lanes".into(),
            "4".into(),
            "--queue-depth".into(),
            "3".into(),
            "--shard-bytes".into(),
            "1048576".into(),
            "--shard-threads".into(),
            "6".into(),
            "--adaptive-bits".into(),
            "--verify".into(),
        ])
        .unwrap();
        let cfg = experiment_config(&args).unwrap();
        assert_eq!(cfg.workload, "vit_tiny");
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.codec.mode, ContextMode::Order0);
        assert_eq!(cfg.codec.bits, 2);
        assert_eq!(cfg.codec.lanes, 4);
        assert_eq!(cfg.queue_depth, 3);
        assert_eq!(cfg.codec.shard_bytes, 1 << 20);
        assert_eq!(cfg.codec.shard_threads, 6);
        assert!(cfg.codec.adaptive_bits);
        assert!(cfg.verify);
    }

    #[test]
    fn lifecycle_flags_override() {
        let args = Args::parse(&[
            "--keyframe-interval".into(),
            "8".into(),
            "--retain-last".into(),
            "4".into(),
            "--retain-every".into(),
            "16".into(),
            "--compact-depth".into(),
            "6".into(),
        ])
        .unwrap();
        let cfg = experiment_config(&args).unwrap();
        assert_eq!(cfg.keyframe_every, 8);
        assert_eq!(cfg.retain_last, 4);
        assert_eq!(cfg.retain_every, 16);
        assert_eq!(cfg.compact_depth, 6);
    }

    #[test]
    fn scrub_and_gc_demand_their_flags() {
        // scrub without --cpcm, gc without a policy: named config errors.
        assert!(run(vec!["scrub".into()]).is_err());
        let err = run(vec![
            "gc".into(),
            "--cpcm".into(),
            "/nonexistent".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("retain"), "{err}");
    }

    #[test]
    fn serve_demands_a_root() {
        let err = run(vec!["serve".into()]).unwrap_err();
        assert!(err.to_string().contains("root"), "{err}");
    }

    #[test]
    fn shard_threads_out_of_range_rejected() {
        let args = Args::parse(&["--shard-threads".into(), "9999".into()]).unwrap();
        assert!(experiment_config(&args).is_err());
    }

    #[test]
    fn tiny_shard_bytes_rejected() {
        let args = Args::parse(&["--shard-bytes".into(), "4".into()]).unwrap();
        assert!(experiment_config(&args).is_err());
    }

    #[test]
    fn zero_queue_depth_rejected() {
        let args = Args::parse(&["--queue-depth".into(), "0".into()]).unwrap();
        assert!(experiment_config(&args).is_err());
    }

    #[test]
    fn lanes_out_of_range_rejected() {
        let args = Args::parse(&["--lanes".into(), "400".into()]).unwrap();
        assert!(experiment_config(&args).is_err());
    }

    #[test]
    fn bad_flag_values_error() {
        let args =
            Args::parse(&["--steps".into(), "abc".into()]).unwrap();
        assert!(experiment_config(&args).is_err());
    }
}

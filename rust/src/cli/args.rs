//! Tiny flag parser (`clap` is unavailable offline).
//!
//! Grammar: `--key value` pairs and bare `--flag` booleans. A `--key`
//! followed by another `--...` token or end-of-input is treated as a
//! boolean flag.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed flags.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse a flag list.
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            let Some(key) = tok.strip_prefix("--") else {
                return Err(Error::config(format!("unexpected positional argument '{tok}'")));
            };
            if key.is_empty() {
                return Err(Error::config("bare '--' not allowed"));
            }
            // Support --key=value too.
            if let Some((k, v)) = key.split_once('=') {
                out.values.insert(k.to_string(), v.to_string());
                i += 1;
                continue;
            }
            match argv.get(i + 1) {
                Some(next) if !next.starts_with("--") => {
                    out.values.insert(key.to_string(), next.clone());
                    i += 2;
                }
                _ => {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            }
        }
        Ok(out)
    }

    /// Value of `--key value`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Required value.
    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| Error::config(format!("missing required flag --{key}")))
    }

    /// True if the bare flag `--key` was given.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Parse `--key value` into `T`, reporting the flag name on failure.
    /// Returns `Ok(None)` when the flag is absent.
    pub fn parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::config(format!("invalid --{key}: '{v}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn pairs_flags_and_equals() {
        let a = Args::parse(&v(&[
            "--steps", "100", "--verify", "--mode=lstm", "--out", "runs/x",
        ]))
        .unwrap();
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("mode"), Some("lstm"));
        assert_eq!(a.get("out"), Some("runs/x"));
        assert!(a.flag("verify"));
        assert!(!a.flag("steps"));
        assert!(a.req("nope").is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&v(&["--compress"])).unwrap();
        assert!(a.flag("compress"));
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' but not '--' is accepted.
        let a = Args::parse(&v(&["--offset", "-5"])).unwrap();
        assert_eq!(a.get("offset"), Some("-5"));
    }

    #[test]
    fn parsed_typed_values() {
        let a = Args::parse(&v(&["--lanes", "8", "--bad", "xyz"])).unwrap();
        assert_eq!(a.parsed::<u64>("lanes").unwrap(), Some(8));
        assert_eq!(a.parsed::<u64>("absent").unwrap(), None);
        assert!(a.parsed::<u64>("bad").is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(&v(&["stray"])).is_err());
        assert!(Args::parse(&v(&["--"])).is_err());
    }
}

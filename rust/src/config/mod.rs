//! Experiment configuration: one JSON document drives the launcher
//! (`cpcm train/compress/...`), the coordinator and the benches.
//!
//! Every field has a sensible default, so `{}` is a valid config; the CLI
//! overrides individual fields from flags.

use crate::codec::{CodecConfig, ContextMode};
use crate::prune::PruneConfig;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// Which probability-model backend to instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(Error::config(format!("unknown backend '{other}'"))),
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Default listen address for `cpcm serve` (loopback: the daemon speaks
/// plaintext HTTP and trusts its tenants' names only after validation —
/// exposing it beyond localhost is a deployment decision, not a default).
pub const SERVE_DEFAULT_ADDR: &str = "127.0.0.1:7070";

/// Default cap on concurrent tenant namespaces for `cpcm serve`.
pub const SERVE_DEFAULT_MAX_TENANTS: usize = 16;

/// Default concurrent-connection cap for `cpcm serve` (the admission
/// semaphore's capacity; accepts beyond it shed with `429`).
pub const SERVE_DEFAULT_MAX_CONNS: usize = 64;

/// Default largest request body `cpcm serve` will buffer (256 MiB —
/// comfortably above the synthetic workloads' raw checkpoints, far below
/// anything that would let one request exhaust the host).
pub const SERVE_DEFAULT_MAX_BODY_BYTES: usize = 256 << 20;

/// Top-level experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Workload program prefix (`lm_tiny`, `lm_small`, `vit_tiny`, …).
    pub workload: String,
    /// Training steps to run.
    pub steps: u64,
    /// Save (and compress) a checkpoint every N steps (paper: 1000 for
    /// Pythia-410M; scaled down for the synthetic workloads).
    pub ckpt_every: u64,
    /// Two-phase capture stress knob: freeze a snapshot into the
    /// compression pipeline every N steps, independently of
    /// `ckpt_every`'s raw saves (0 ⇒ follow `ckpt_every`). Lower values
    /// capture more often than the pipeline drains, exercising the
    /// bounded one-in-flight handoff.
    pub snapshot_cadence: u64,
    /// Reference step size `s` of paper Eq. 6 (1 ⇒ previous checkpoint).
    pub step_size: u64,
    /// Force a self-contained (intra) frame every N checkpoints; 0 ⇒ only
    /// the first. (Accepted under the alias `keyframe_interval` too.)
    pub keyframe_every: u64,
    /// Retention: keep only the newest N checkpoints (0 ⇒ keep all).
    /// Ancestors a retained step depends on are never collected.
    pub retain_last: u64,
    /// Retention: additionally keep every Mth checkpoint (0 ⇒ off).
    pub retain_every: u64,
    /// Rebase a chain onto a lossless keyframe once restore depth
    /// exceeds this many containers (0 ⇒ never compact).
    pub compact_depth: u64,
    /// Training seed.
    pub seed: u64,
    /// Artifacts directory (AOT programs).
    pub artifacts_dir: String,
    /// Output directory (raw + compressed checkpoints, logs).
    pub out_dir: String,
    /// Probability-model backend.
    pub backend: BackendKind,
    /// Decode-and-verify every container right after encoding.
    pub verify: bool,
    /// Depth of the coordinator's submission queue and of each pipeline
    /// stage queue (backpressure bound; ≥ 1).
    pub queue_depth: usize,
    /// Codec settings.
    pub codec: CodecConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            workload: "lm_tiny".into(),
            steps: 300,
            ckpt_every: 50,
            snapshot_cadence: 0,
            step_size: 1,
            keyframe_every: 0,
            retain_last: 0,
            retain_every: 0,
            compact_depth: 0,
            seed: 42,
            artifacts_dir: "artifacts".into(),
            out_dir: "runs/default".into(),
            backend: BackendKind::Native,
            verify: false,
            queue_depth: 2,
            codec: CodecConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from JSON text (unknown fields rejected to catch typos).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut cfg = Self::default();
        let obj = j.as_obj().ok_or_else(|| Error::config("config must be an object"))?;
        for (key, val) in obj {
            match key.as_str() {
                "workload" => cfg.workload = req_str(val)?,
                "steps" => cfg.steps = req_u64(val)?,
                "ckpt_every" => cfg.ckpt_every = req_u64(val)?,
                "snapshot_cadence" => cfg.snapshot_cadence = req_u64(val)?,
                "step_size" => cfg.step_size = req_u64(val)?,
                "keyframe_every" | "keyframe_interval" => cfg.keyframe_every = req_u64(val)?,
                "retain_last" => cfg.retain_last = req_u64(val)?,
                "retain_every" => cfg.retain_every = req_u64(val)?,
                "compact_depth" => cfg.compact_depth = req_u64(val)?,
                "seed" => cfg.seed = req_u64(val)?,
                "artifacts_dir" => cfg.artifacts_dir = req_str(val)?,
                "out_dir" => cfg.out_dir = req_str(val)?,
                "backend" => cfg.backend = BackendKind::parse(&req_str(val)?)?,
                "verify" => {
                    cfg.verify =
                        val.as_bool().ok_or_else(|| Error::config("verify must be bool"))?
                }
                "queue_depth" => cfg.queue_depth = req_u64(val)? as usize,
                "codec" => apply_codec(&mut cfg.codec, val)?,
                other => return Err(Error::config(format!("unknown config key '{other}'"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_json_text(&std::fs::read_to_string(path)?)
    }

    /// Serialize (for run provenance logs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::str(self.workload.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("ckpt_every", Json::num(self.ckpt_every as f64)),
            ("snapshot_cadence", Json::num(self.snapshot_cadence as f64)),
            ("step_size", Json::num(self.step_size as f64)),
            ("keyframe_every", Json::num(self.keyframe_every as f64)),
            ("retain_last", Json::num(self.retain_last as f64)),
            ("retain_every", Json::num(self.retain_every as f64)),
            ("compact_depth", Json::num(self.compact_depth as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("out_dir", Json::str(self.out_dir.clone())),
            ("backend", Json::str(self.backend.as_str())),
            ("verify", Json::Bool(self.verify)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            (
                "codec",
                Json::obj(vec![
                    ("mode", Json::str(mode_str(self.codec.mode))),
                    ("bits", Json::num(self.codec.bits as f64)),
                    ("window", Json::num(self.codec.window as f64)),
                    ("hidden", Json::num(self.codec.hidden as f64)),
                    ("embed", Json::num(self.codec.embed as f64)),
                    ("batch", Json::num(self.codec.batch as f64)),
                    ("alpha", Json::num(self.codec.prune.alpha)),
                    ("beta", Json::num(self.codec.prune.beta)),
                    ("log_moment2", Json::Bool(self.codec.log_moment2)),
                    ("lanes", Json::num(self.codec.lanes as f64)),
                    ("shard_bytes", Json::num(self.codec.shard_bytes as f64)),
                    ("shard_threads", Json::num(self.codec.shard_threads as f64)),
                    ("adaptive_bits", Json::Bool(self.codec.adaptive_bits)),
                ]),
            ),
        ])
    }

    /// Check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.ckpt_every == 0 || self.steps == 0 {
            return Err(Error::config("steps and ckpt_every must be positive"));
        }
        if self.step_size == 0 {
            return Err(Error::config("step_size must be >= 1"));
        }
        if self.queue_depth == 0 {
            return Err(Error::config("queue_depth must be >= 1"));
        }
        if self.codec.window % 2 == 0 {
            return Err(Error::config("codec.window must be odd"));
        }
        if self.codec.bits == 0 || self.codec.bits > 8 {
            return Err(Error::config("codec.bits must be in 1..=8"));
        }
        if self.codec.lanes > crate::codec::MAX_LANES {
            return Err(Error::config(format!(
                "codec.lanes must be 0 (auto) or 1..={}",
                crate::codec::MAX_LANES
            )));
        }
        // Mirror the decoder's untrusted-header caps so every container we
        // can be configured to write is one any decoder will accept.
        if self.codec.window > 31 {
            return Err(Error::config("codec.window must be <= 31"));
        }
        if self.codec.hidden == 0
            || self.codec.hidden > 1024
            || self.codec.embed == 0
            || self.codec.embed > 1024
        {
            return Err(Error::config("codec.hidden/embed must be in 1..=1024"));
        }
        if self.codec.layers == 0 || self.codec.layers > 16 {
            return Err(Error::config("codec.layers must be in 1..=16"));
        }
        if self.codec.batch == 0 || self.codec.batch > 8192 {
            return Err(Error::config("codec.batch must be in 1..=8192"));
        }
        if self.codec.shard_bytes > 0 && self.codec.shard_bytes < 12 {
            return Err(Error::config(
                "codec.shard_bytes must be 0 (unsharded) or >= 12 (one position)",
            ));
        }
        if self.codec.shard_threads > crate::codec::MAX_SHARD_THREADS {
            return Err(Error::config(format!(
                "codec.shard_threads must be 0 (auto) or 1..={}",
                crate::codec::MAX_SHARD_THREADS
            )));
        }
        Ok(())
    }
}

fn mode_str(m: ContextMode) -> &'static str {
    match m {
        ContextMode::Lstm => "lstm",
        ContextMode::ZeroContext => "zero_context",
        ContextMode::Mixed => "mixed",
        ContextMode::Order0 => "order0",
    }
}

fn apply_codec(c: &mut CodecConfig, j: &Json) -> Result<()> {
    let obj = j.as_obj().ok_or_else(|| Error::config("codec must be an object"))?;
    for (key, val) in obj {
        match key.as_str() {
            "mode" => {
                c.mode = match req_str(val)?.as_str() {
                    "lstm" => ContextMode::Lstm,
                    "zero_context" => ContextMode::ZeroContext,
                    "mixed" => ContextMode::Mixed,
                    "order0" => ContextMode::Order0,
                    other => return Err(Error::config(format!("unknown mode '{other}'"))),
                }
            }
            "bits" => c.bits = req_u64(val)? as u8,
            "window" => c.window = req_u64(val)? as usize,
            "hidden" => c.hidden = req_u64(val)? as usize,
            "embed" => c.embed = req_u64(val)? as usize,
            "layers" => c.layers = req_u64(val)? as usize,
            "batch" => c.batch = req_u64(val)? as usize,
            "seed" => c.seed = req_u64(val)?,
            "alpha" => {
                c.prune = PruneConfig { alpha: req_f64(val)?, ..c.prune };
            }
            "beta" => {
                c.prune = PruneConfig { beta: req_f64(val)?, ..c.prune };
            }
            "prune_enabled" => {
                c.prune = PruneConfig {
                    enabled: val.as_bool().ok_or_else(|| Error::config("bool expected"))?,
                    ..c.prune
                };
            }
            "log_moment2" => {
                c.log_moment2 = val.as_bool().ok_or_else(|| Error::config("bool expected"))?
            }
            "quant_iters" => c.quant_iters = req_u64(val)? as usize,
            "lr" => c.lr = req_f64(val)? as f32,
            "warmup_passes" => c.warmup_passes = req_u64(val)? as usize,
            "warmup_stride" => c.warmup_stride = (req_u64(val)? as usize).max(1),
            // 0 = auto (available hardware threads).
            "lanes" => c.lanes = req_u64(val)? as usize,
            // 0 = unsharded (format 2); >0 = streaming format 3 with this
            // many raw value bytes per shard (~64 MiB is a good default).
            "shard_bytes" => c.shard_bytes = req_u64(val)? as usize,
            // Shard-scheduler parallelism (and streaming look-ahead);
            // 0 = auto (available hardware threads). Never affects bytes.
            "shard_threads" => c.shard_threads = req_u64(val)? as usize,
            // Per-fragment dynamic bit allocation (format 5); the global
            // `bits` stays the default width and the hard ceiling.
            "adaptive_bits" => {
                c.adaptive_bits = val.as_bool().ok_or_else(|| Error::config("bool expected"))?
            }
            other => return Err(Error::config(format!("unknown codec key '{other}'"))),
        }
    }
    Ok(())
}

fn req_str(v: &Json) -> Result<String> {
    v.as_str().map(|s| s.to_string()).ok_or_else(|| Error::config("string expected"))
}
fn req_u64(v: &Json) -> Result<u64> {
    v.as_u64().ok_or_else(|| Error::config("non-negative integer expected"))
}
fn req_f64(v: &Json) -> Result<f64> {
    v.as_f64().ok_or_else(|| Error::config("number expected"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_defaults_are_loopback_and_bounded() {
        assert!(SERVE_DEFAULT_ADDR.starts_with("127.0.0.1:"));
        assert!(SERVE_DEFAULT_MAX_TENANTS > 0);
        assert!(SERVE_DEFAULT_MAX_CONNS > 0);
        assert!(SERVE_DEFAULT_MAX_BODY_BYTES >= 1 << 20);
    }

    #[test]
    fn lifecycle_knobs_parse_and_alias() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{"keyframe_interval": 8, "retain_last": 4, "retain_every": 10, "compact_depth": 6}"#,
        )
        .unwrap();
        assert_eq!(cfg.keyframe_every, 8);
        assert_eq!(cfg.retain_last, 4);
        assert_eq!(cfg.retain_every, 10);
        assert_eq!(cfg.compact_depth, 6);
        let back = ExperimentConfig::from_json_text(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.keyframe_every, 8);
        assert_eq!(back.compact_depth, 6);
    }

    #[test]
    fn empty_config_is_default() {
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.workload, "lm_tiny");
        assert_eq!(cfg.backend, BackendKind::Native);
    }

    #[test]
    fn full_roundtrip() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{
              "workload": "lm_small", "steps": 100, "ckpt_every": 20,
              "step_size": 2, "seed": 7, "backend": "pjrt", "verify": true,
              "queue_depth": 4,
              "codec": {"mode": "zero_context", "bits": 2, "window": 5,
                        "hidden": 32, "alpha": 1e-4, "log_moment2": false,
                        "lanes": 8, "shard_bytes": 1048576}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.workload, "lm_small");
        assert_eq!(cfg.step_size, 2);
        assert_eq!(cfg.queue_depth, 4);
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        assert_eq!(cfg.codec.mode, ContextMode::ZeroContext);
        assert_eq!(cfg.codec.bits, 2);
        assert_eq!(cfg.codec.window, 5);
        assert_eq!(cfg.codec.prune.alpha, 1e-4);
        assert!(!cfg.codec.log_moment2);
        assert_eq!(cfg.codec.lanes, 8);
        assert_eq!(cfg.codec.shard_bytes, 1 << 20);
        // Provenance serialization parses back.
        let j = cfg.to_json().to_string();
        assert!(Json::parse(&j).is_ok());
    }

    #[test]
    fn typos_rejected() {
        assert!(ExperimentConfig::from_json_text(r#"{"workloda": "x"}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"bitz": 4}}"#).is_err());
    }

    #[test]
    fn invariants_enforced() {
        assert!(ExperimentConfig::from_json_text(r#"{"ckpt_every": 0}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"window": 4}}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"bits": 9}}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"step_size": 0}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"queue_depth": 0}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"lanes": 65}}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"lanes": 0}}"#).is_ok());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"shard_bytes": 4}}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"shard_bytes": 0}}"#).is_ok());
        assert!(
            ExperimentConfig::from_json_text(r#"{"codec": {"shard_threads": 5000}}"#).is_err()
        );
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"shard_threads": 0}}"#).is_ok());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"shard_threads": 8}}"#).is_ok());
        assert!(
            ExperimentConfig::from_json_text(r#"{"codec": {"shard_bytes": 67108864}}"#).is_ok()
        );
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"window": 257}}"#).is_err());
        assert!(ExperimentConfig::from_json_text(r#"{"codec": {"batch": 0}}"#).is_err());
    }

    #[test]
    fn unknown_backend_rejected() {
        assert!(ExperimentConfig::from_json_text(r#"{"backend": "gpu"}"#).is_err());
    }
}

//! Non-uniform quantization — paper §II, last paragraph.
//!
//! "The pruned values are set to zero, and the remaining parameters are
//! clustered using the k-means algorithm to `2^n − 1` cluster centers.
//! These clusters are stored as indices and centers."
//!
//! Symbol 0 is reserved for exact zero (pruned positions); symbols
//! `1 ..= 2^n − 1` index the k-means centers, which are kept sorted
//! ascending so that symbol magnitude correlates with value magnitude —
//! this gives the LSTM context model a meaningful ordinal alphabet.
//!
//! The quantizer is deterministic: k-means++ seeding uses a fixed-seed
//! [`Pcg64`] stream, and fitting subsamples deterministically when the
//! input exceeds `sample_cap`.

use crate::util::bitio;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Quantizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// Bits per symbol `n`; alphabet is `2^n` (zero + `2^n − 1` centers).
    pub bits: u8,
    /// Lloyd iterations after k-means++ seeding.
    pub iters: usize,
    /// Max values used to *fit* centers (assignment always covers all).
    pub sample_cap: usize,
    /// PRNG seed for k-means++ (fixed ⇒ reproducible artifacts).
    pub seed: u64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self { bits: 4, iters: 12, sample_cap: 1 << 16, seed: 0x5eed }
    }
}

impl QuantConfig {
    /// Alphabet size `2^n`.
    pub fn alphabet(&self) -> usize {
        1usize << self.bits
    }
    /// Number of k-means centers `2^n − 1`.
    pub fn centers(&self) -> usize {
        self.alphabet() - 1
    }
}

/// Quantization result for one tensor: per-element symbols plus the center
/// table. `symbols[i] == 0` ⇔ the element is exactly zero.
#[derive(Clone, Debug, PartialEq)]
pub struct Quantized {
    pub symbols: Vec<u16>,
    /// Sorted ascending; `centers[s-1]` is the value of symbol `s`.
    pub centers: Vec<f32>,
}

impl Quantized {
    /// Reconstruct values (the lossy inverse).
    pub fn dequantize(&self) -> Vec<f32> {
        self.symbols
            .iter()
            .map(|&s| if s == 0 { 0.0 } else { self.centers[s as usize - 1] })
            .collect()
    }

    /// Pack symbols at `bits` per symbol (paper: "multiple lower-precision
    /// numbers … combined into a single higher-precision number").
    pub fn pack(&self, bits: u8) -> Vec<u8> {
        bitio::pack_symbols(&self.symbols, bits)
    }
}

/// Unpack symbols previously packed with [`Quantized::pack`].
pub fn unpack(buf: &[u8], bits: u8, count: usize) -> Result<Vec<u16>> {
    bitio::unpack_symbols(buf, bits, count)
}

/// Quantize `values` under `cfg`. Zeros map to symbol 0; non-zeros are
/// k-means-clustered to `2^n − 1` centers.
pub fn quantize(values: &[f32], cfg: &QuantConfig) -> Result<Quantized> {
    if cfg.bits == 0 || cfg.bits > 12 {
        return Err(Error::config(format!("quant bits {} out of range 1..=12", cfg.bits)));
    }
    let nonzero: Vec<f32> = values.iter().copied().filter(|&x| x != 0.0).collect();
    let centers = fit_centers(&nonzero, cfg);
    let symbols = assign(values, &centers);
    Ok(Quantized { symbols, centers })
}

/// Fit `2^n − 1` sorted centers to the nonzero values.
fn fit_centers(nonzero: &[f32], cfg: &QuantConfig) -> Vec<f32> {
    let k = cfg.centers();
    if nonzero.is_empty() {
        return Vec::new();
    }
    // Deterministic subsample for fitting.
    let sample: Vec<f32> = if nonzero.len() > cfg.sample_cap {
        let stride = nonzero.len() as f64 / cfg.sample_cap as f64;
        (0..cfg.sample_cap).map(|i| nonzero[(i as f64 * stride) as usize]).collect()
    } else {
        nonzero.to_vec()
    };

    // Fewer distinct values than centers → exact representation.
    let mut distinct = sample.clone();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    if distinct.len() <= k {
        return distinct;
    }

    let mut centers = kmeans_pp_seed(&sample, k, cfg.seed);
    lloyd(&sample, &mut centers, cfg.iters);
    centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centers.dedup();
    centers
}

/// k-means++ seeding (deterministic PRNG).
fn kmeans_pp_seed(xs: &[f32], k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, xs.len() as u64);
    let mut centers = Vec::with_capacity(k);
    centers.push(xs[rng.below_usize(xs.len())]);
    let mut d2: Vec<f64> = xs.iter().map(|&x| dist2(x, centers[0])).collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All points coincide with a center; any point works.
            xs[rng.below_usize(xs.len())]
        } else {
            let mut t = rng.f64() * total;
            let mut idx = xs.len() - 1;
            for (i, &d) in d2.iter().enumerate() {
                t -= d;
                if t < 0.0 {
                    idx = i;
                    break;
                }
            }
            xs[idx]
        };
        centers.push(next);
        for (i, &x) in xs.iter().enumerate() {
            let d = dist2(x, next);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centers
}

#[inline]
fn dist2(a: f32, b: f32) -> f64 {
    let d = a as f64 - b as f64;
    d * d
}

/// Lloyd iterations specialized for 1-D: sort centers, assign by midpoint
/// binary search, recompute means. Empty clusters are respawned at the
/// point farthest from its center.
fn lloyd(xs: &[f32], centers: &mut Vec<f32>, iters: usize) {
    for _ in 0..iters {
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mids = midpoints(centers);
        let mut sums = vec![0.0f64; centers.len()];
        let mut counts = vec![0usize; centers.len()];
        let mut far: Vec<(f64, f32)> = vec![(-1.0, 0.0); centers.len()];
        for &x in xs {
            let c = mids.partition_point(|&m| m < x);
            sums[c] += x as f64;
            counts[c] += 1;
            let d = dist2(x, centers[c]);
            if d > far[c].0 {
                far[c] = (d, x);
            }
        }
        // Respawn empties at the globally farthest point.
        let global_far = far
            .iter()
            .cloned()
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap_or((0.0, 0.0))
            .1;
        let mut moved = false;
        for i in 0..centers.len() {
            if counts[i] > 0 {
                let new = (sums[i] / counts[i] as f64) as f32;
                if new != centers[i] {
                    moved = true;
                }
                centers[i] = new;
            } else {
                centers[i] = global_far;
                moved = true;
            }
        }
        if !moved {
            break;
        }
    }
    centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

/// Decision boundaries between adjacent sorted centers — the table the
/// assignment kernel searches/counts against (public for the kernel
/// benches and the batch≡scalar battery).
pub fn midpoints(sorted_centers: &[f32]) -> Vec<f32> {
    sorted_centers.windows(2).map(|w| (w[0] + w[1]) / 2.0).collect()
}

/// Assign every value to a symbol: 0 for exact zero, otherwise the nearest
/// center's index + 1. The hot loop lives in [`crate::codec::kernels`]: a
/// chunked branchless counting kernel with the original midpoint binary
/// search kept as its scalar reference — bit-identical by construction,
/// since counting `mids < x` over the sorted table *is* `partition_point`.
pub fn assign(values: &[f32], centers: &[f32]) -> Vec<u16> {
    if centers.is_empty() {
        return vec![0; values.len()];
    }
    let mids = midpoints(centers);
    let mut out = vec![0u16; values.len()];
    crate::codec::kernels::assign_into(values, &mids, &mut out);
    out
}

/// Mean squared quantization error (diagnostics / ablations).
pub fn mse(values: &[f32], q: &Quantized) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let deq = q.dequantize();
    values
        .iter()
        .zip(&deq)
        .map(|(&a, &b)| dist2(a, b))
        .sum::<f64>()
        / values.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn zeros_map_to_symbol_zero() {
        let vals = [0.0f32, 1.0, 0.0, -1.0, 0.0];
        let q = quantize(&vals, &QuantConfig::default()).unwrap();
        assert_eq!(q.symbols[0], 0);
        assert_eq!(q.symbols[2], 0);
        assert_eq!(q.symbols[4], 0);
        assert_ne!(q.symbols[1], 0);
        assert_ne!(q.symbols[3], 0);
    }

    #[test]
    fn few_distinct_values_are_exact() {
        let vals = [0.5f32, -0.25, 0.5, 0.75, -0.25, 0.0];
        let q = quantize(&vals, &QuantConfig { bits: 2, ..Default::default() }).unwrap();
        // 3 distinct non-zeros fit exactly into 2^2−1 = 3 centers.
        assert_eq!(q.dequantize(), vals.to_vec());
    }

    #[test]
    fn centers_sorted_ascending() {
        let mut g = Pcg64::seed(5);
        let vals: Vec<f32> = (0..5000).map(|_| g.normal_f32()).collect();
        let q = quantize(&vals, &QuantConfig::default()).unwrap();
        for w in q.centers.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn more_bits_reduce_mse() {
        let mut g = Pcg64::seed(6);
        let vals: Vec<f32> = (0..8000).map(|_| g.normal_f32() * 0.01).collect();
        let q2 = quantize(&vals, &QuantConfig { bits: 2, ..Default::default() }).unwrap();
        let q4 = quantize(&vals, &QuantConfig { bits: 4, ..Default::default() }).unwrap();
        let q6 = quantize(&vals, &QuantConfig { bits: 6, ..Default::default() }).unwrap();
        let (e2, e4, e6) = (mse(&vals, &q2), mse(&vals, &q4), mse(&vals, &q6));
        assert!(e4 < e2, "e4={e4} e2={e2}");
        assert!(e6 < e4, "e6={e6} e4={e4}");
    }

    #[test]
    fn deterministic() {
        let mut g = Pcg64::seed(7);
        let vals: Vec<f32> = (0..4000).map(|_| g.normal_f32()).collect();
        let a = quantize(&vals, &QuantConfig::default()).unwrap();
        let b = quantize(&vals, &QuantConfig::default()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut g = Pcg64::seed(8);
        let vals: Vec<f32> =
            (0..1000).map(|_| if g.f64() < 0.8 { 0.0 } else { g.normal_f32() }).collect();
        let cfg = QuantConfig { bits: 4, ..Default::default() };
        let q = quantize(&vals, &cfg).unwrap();
        let packed = q.pack(cfg.bits);
        assert_eq!(packed.len(), vals.len().div_ceil(2));
        let syms = unpack(&packed, cfg.bits, vals.len()).unwrap();
        assert_eq!(syms, q.symbols);
    }

    #[test]
    fn symbols_within_alphabet() {
        forall("quant alphabet bound", 20, |g| {
            let n = g.size(3000).max(1);
            let sparsity = g.rng().f64();
            let vals = g.sparse_residuals(n, sparsity, 0.05);
            let bits = *g.choose(&[2u8, 3, 4, 5]);
            let cfg = QuantConfig { bits, ..Default::default() };
            let q = quantize(&vals, &cfg).unwrap();
            let alphabet = 1u16 << bits;
            for (&v, &s) in vals.iter().zip(&q.symbols) {
                assert!(s < alphabet);
                assert_eq!(s == 0, v == 0.0, "zero symbol iff zero value");
            }
        });
    }

    #[test]
    fn assignment_is_nearest_center() {
        forall("quant nearest center", 15, |g| {
            let n = g.size(800).max(1);
            let vals = g.sparse_residuals(n, 0.5, 1.0);
            let q = quantize(&vals, &QuantConfig { bits: 3, ..Default::default() }).unwrap();
            for (&v, &s) in vals.iter().zip(&q.symbols) {
                if v == 0.0 {
                    continue;
                }
                let assigned = q.centers[s as usize - 1];
                let best = q
                    .centers
                    .iter()
                    .map(|&c| dist2(v, c))
                    .fold(f64::INFINITY, f64::min);
                assert!(dist2(v, assigned) <= best + 1e-12);
            }
        });
    }

    #[test]
    fn empty_and_all_zero_inputs() {
        let q = quantize(&[], &QuantConfig::default()).unwrap();
        assert!(q.symbols.is_empty());
        assert!(q.centers.is_empty());
        let q = quantize(&[0.0; 10], &QuantConfig::default()).unwrap();
        assert_eq!(q.symbols, vec![0u16; 10]);
        assert!(q.centers.is_empty());
        assert_eq!(q.dequantize(), vec![0.0f32; 10]);
    }

    #[test]
    fn bad_bits_rejected() {
        assert!(quantize(&[1.0], &QuantConfig { bits: 0, ..Default::default() }).is_err());
        assert!(quantize(&[1.0], &QuantConfig { bits: 13, ..Default::default() }).is_err());
    }

    use crate::util::rng::Pcg64;
}

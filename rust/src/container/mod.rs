//! `.cpcm` compressed-checkpoint container format.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    [8]  = "CPCM0001"
//! hdr_len  u32
//! header   [hdr_len]   JSON (format, step, ref_step, codec config incl.
//!                      lane count, tensor list, per-set stats)
//! n_blobs  u32
//! blobs    n × (u32 len, bytes)   order defined by the codec
//! crc32    u32         over everything before it
//! ```
//!
//! The byte framing is shared by both header **formats**; only the blob
//! layout and stream semantics differ (dispatched on the header's
//! `format` field, see [`crate::codec`]):
//!
//! - `format: 1` (legacy) — per parameter set: `n_tensors` center tables,
//!   then **one** arithmetic stream covering the whole set;
//! - `format: 2` (lane-parallel) — per parameter set: `n_tensors` center
//!   tables, then `codec.lanes` independent arithmetic lane streams, each
//!   coding a fixed-size contiguous shard of the set's symbol sequence
//!   with its own model replica. Lane blob index within a set:
//!   `k * (n_tensors + lanes) + n_tensors + lane`.
//!
//! The header is self-describing: `cpcm info file.cpcm` pretty-prints it,
//! and the decoder rebuilds its models purely from header fields (plus the
//! reference checkpoint and chain symbol maps — see [`crate::codec`]).
//!
//! A directory of containers written by the coordinator additionally
//! carries a `manifest.json` index (step → file, reference parent,
//! trailer CRC — see [`crate::coordinator::ChainManifest`]); the trailer
//! CRC is readable without parsing via [`Container::stored_crc`].

use crate::util::json::Json;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"CPCM0001";

/// A parsed (or under-construction) container.
#[derive(Clone, Debug, PartialEq)]
pub struct Container {
    /// Header document.
    pub header: Json,
    /// Opaque blob sections, in codec-defined order.
    pub blobs: Vec<Vec<u8>>,
}

impl Container {
    /// New container with the given header.
    pub fn new(header: Json) -> Self {
        Self { header, blobs: Vec::new() }
    }

    /// Append a blob, returning its index.
    pub fn push_blob(&mut self, blob: Vec<u8>) -> usize {
        self.blobs.push(blob);
        self.blobs.len() - 1
    }

    /// Blob by index.
    pub fn blob(&self, i: usize) -> Result<&[u8]> {
        self.blobs
            .get(i)
            .map(|b| b.as_slice())
            .ok_or_else(|| Error::format(format!("container missing blob {i}")))
    }

    /// Serialize with trailing CRC.
    pub fn to_bytes(&self) -> Vec<u8> {
        let header = self.header.to_string();
        let mut out = Vec::with_capacity(
            header.len() + self.blobs.iter().map(|b| b.len() + 4).sum::<usize>() + 64,
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&(self.blobs.len() as u32).to_le_bytes());
        for b in &self.blobs {
            out.extend_from_slice(&(b.len() as u32).to_le_bytes());
            out.extend_from_slice(b);
        }
        let crc = crate::util::crc32::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and CRC-check.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 + 4 + 4 + 4 || &bytes[..8] != MAGIC {
            return Err(Error::format("not a cpcm container"));
        }
        let body_len = bytes.len() - 4;
        let stored_crc = u32::from_le_bytes(bytes[body_len..].try_into().unwrap());
        if crate::util::crc32::hash(&bytes[..body_len]) != stored_crc {
            return Err(Error::format("container CRC mismatch (corrupt file)"));
        }
        let mut pos = 8usize;
        let take_u32 = |pos: &mut usize| -> Result<u32> {
            if *pos + 4 > body_len {
                return Err(Error::format("container truncated"));
            }
            let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let hdr_len = take_u32(&mut pos)? as usize;
        if pos + hdr_len > body_len {
            return Err(Error::format("container truncated in header"));
        }
        let header_text = std::str::from_utf8(&bytes[pos..pos + hdr_len])
            .map_err(|_| Error::format("header not utf-8"))?;
        let header = Json::parse(header_text)?;
        pos += hdr_len;
        let n_blobs = take_u32(&mut pos)? as usize;
        // Each declared blob needs at least its 4-byte length field, so a
        // forged count cannot drive the allocation past the input size.
        if n_blobs > (body_len - pos) / 4 {
            return Err(Error::format("container declares more blobs than fit"));
        }
        let mut blobs = Vec::with_capacity(n_blobs);
        for _ in 0..n_blobs {
            let len = take_u32(&mut pos)? as usize;
            if pos + len > body_len {
                return Err(Error::format("container truncated in blob"));
            }
            blobs.push(bytes[pos..pos + len].to_vec());
            pos += len;
        }
        if pos != body_len {
            return Err(Error::format("trailing bytes in container"));
        }
        Ok(Self { header, blobs })
    }

    /// The CRC-32 recorded in a serialized container's trailer (the last
    /// four bytes), read without parsing or checksumming the body. The
    /// chain manifest ([`crate::coordinator::ChainManifest`]) stores this
    /// value so a restore can reject a swapped or stale container before
    /// any entropy decoding starts; [`Container::from_bytes`] still
    /// re-verifies the checksum over the full body.
    pub fn stored_crc(bytes: &[u8]) -> Result<u32> {
        if bytes.len() < 8 + 4 + 4 + 4 || &bytes[..8] != MAGIC {
            return Err(Error::format("not a cpcm container"));
        }
        let tail: [u8; 4] = bytes[bytes.len() - 4..].try_into().unwrap();
        Ok(u32::from_le_bytes(tail))
    }

    /// Total serialized size (compression-ratio denominator).
    pub fn size_bytes(&self) -> usize {
        8 + 4
            + self.header.to_string().len()
            + 4
            + self.blobs.iter().map(|b| b.len() + 4).sum::<usize>()
            + 4
    }
}

/// Streaming writer producing byte-identical output to
/// [`Container::to_bytes`] without holding more than one blob in memory.
///
/// The container framing is stream-friendly by construction: the header
/// and blob count go first, each blob is self-delimiting, and the trailer
/// CRC folds incrementally ([`crate::util::crc32::Crc32`]). The format-3
/// encoder uses this to push shard blobs to disk as they finish — peak
/// encoder memory stays bounded by the shard budget — while the in-memory
/// path writes into a `Vec<u8>` sink and gets the exact same bytes.
///
/// The blob count must be known up front (it is derivable from the header
/// for every format) and [`ContainerStreamWriter::finish`] enforces it.
pub struct ContainerStreamWriter<W: std::io::Write> {
    w: W,
    crc: crate::util::crc32::Crc32,
    /// Bytes written so far (also the next blob's file offset).
    written: u64,
    declared_blobs: u32,
    pushed_blobs: u32,
}

impl<W: std::io::Write> ContainerStreamWriter<W> {
    /// Write the container prefix (magic, header, blob count).
    pub fn new(mut w: W, header: &Json, n_blobs: u32) -> Result<Self> {
        let header = header.to_string();
        let mut crc = crate::util::crc32::Crc32::new();
        let mut written = 0u64;
        let mut emit = |w: &mut W, bytes: &[u8]| -> Result<()> {
            w.write_all(bytes)?;
            crc.update(bytes);
            written += bytes.len() as u64;
            Ok(())
        };
        emit(&mut w, MAGIC)?;
        emit(&mut w, &(header.len() as u32).to_le_bytes())?;
        emit(&mut w, header.as_bytes())?;
        emit(&mut w, &n_blobs.to_le_bytes())?;
        Ok(Self { w, crc, written, declared_blobs: n_blobs, pushed_blobs: 0 })
    }

    /// Current file offset — the offset the *next* blob's length field
    /// will land at (recorded in the format-3 shard index).
    pub fn offset(&self) -> u64 {
        self.written
    }

    /// Append one blob (length prefix + payload).
    pub fn push_blob(&mut self, blob: &[u8]) -> Result<()> {
        if self.pushed_blobs == self.declared_blobs {
            return Err(Error::format("more blobs pushed than declared"));
        }
        let len = (blob.len() as u32).to_le_bytes();
        self.w.write_all(&len)?;
        self.crc.update(&len);
        self.w.write_all(blob)?;
        self.crc.update(blob);
        self.written += 4 + blob.len() as u64;
        self.pushed_blobs += 1;
        Ok(())
    }

    /// Write the trailer CRC and flush; returns the total container size.
    pub fn finish(mut self) -> Result<u64> {
        if self.pushed_blobs != self.declared_blobs {
            return Err(Error::format(format!(
                "container declared {} blobs but {} were written",
                self.declared_blobs, self.pushed_blobs
            )));
        }
        let crc = self.crc.finalize();
        self.w.write_all(&crc.to_le_bytes())?;
        self.w.flush()?;
        Ok(self.written + 4)
    }
}

/// Seekable range-reader over a serialized container file.
///
/// [`Container::from_bytes`] loads every blob at once; this reader is the
/// larger-than-RAM counterpart used by
/// [`crate::codec::sharded::decode_streaming`]: `open` verifies the
/// trailer CRC in a chunked pass (O(1) memory), parses the header, and
/// then serves framed blob runs by offset — the format-3 shard index
/// supplies the offsets, so a shard-by-shard decode only ever holds the
/// blobs of the shards currently in flight (one, for a sequential walk;
/// the shard scheduler's look-ahead window otherwise).
pub struct ContainerFileReader {
    file: std::fs::File,
    header: Json,
    /// Total file size (including the 4-byte CRC trailer).
    file_len: u64,
    /// Blob count declared by the framing.
    n_blobs: u32,
    /// Offset of the first blob's length field.
    blobs_start: u64,
    /// The trailer CRC (verified against the body by [`Self::open`];
    /// only read by [`Self::open_streaming`]).
    stored_crc: u32,
    /// Running CRC over the prefix bytes `[0, blobs_start)` — the seed a
    /// sequential reader continues with the framed blob bytes to verify
    /// the trailer without a second pass (see [`Self::prefix_crc`]).
    prefix_crc: crate::util::crc32::Crc32,
}

impl ContainerFileReader {
    /// Open `path`: validate magic and framing, verify the trailer CRC
    /// over the whole body in fixed-size chunks (O(1) memory), and parse
    /// the header.
    pub fn open(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::open_with(path, true)
    }

    /// [`ContainerFileReader::open`] WITHOUT the whole-body CRC pass —
    /// for shard-by-shard readers that verify each format-3 shard's index
    /// CRC as they range-read it ([`crate::codec::sharded::decode_streaming`]),
    /// where re-hashing the whole file first would double checksum cost
    /// and add a full sequential read pass per larger-than-RAM restore.
    /// Magic, framing and header are still validated, and the trailer CRC
    /// value is still read (for manifest comparison via
    /// [`ContainerFileReader::stored_crc`]) — it is just not recomputed.
    pub fn open_streaming(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::open_with(path, false)
    }

    fn open_with(path: impl AsRef<std::path::Path>, verify_body: bool) -> Result<Self> {
        use std::io::{Read, Seek, SeekFrom};
        let mut file = std::fs::File::open(path.as_ref())?;
        let file_len = file.metadata()?.len();
        if file_len < (8 + 4 + 4 + 4) as u64 {
            return Err(Error::format("not a cpcm container"));
        }
        let body_len = file_len - 4;

        // Prefix: magic, header, blob count — rejected before any
        // body-sized work happens; CRC'd as read (see `prefix_crc`).
        let mut prefix_crc = crate::util::crc32::Crc32::new();
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)?;
        if magic != *MAGIC {
            return Err(Error::format("not a cpcm container"));
        }
        prefix_crc.update(&magic);
        let mut b4 = [0u8; 4];
        file.read_exact(&mut b4)?;
        prefix_crc.update(&b4);
        let hdr_len = u32::from_le_bytes(b4) as u64;
        if 8 + 4 + hdr_len + 4 > body_len {
            return Err(Error::format("container truncated in header"));
        }
        let mut hdr_bytes = vec![0u8; hdr_len as usize];
        file.read_exact(&mut hdr_bytes)?;
        prefix_crc.update(&hdr_bytes);
        let header_text = std::str::from_utf8(&hdr_bytes)
            .map_err(|_| Error::format("header not utf-8"))?;
        let header = Json::parse(header_text)?;
        file.read_exact(&mut b4)?;
        prefix_crc.update(&b4);
        let n_blobs = u32::from_le_bytes(b4);
        let blobs_start = 8 + 4 + hdr_len + 4;
        // Each declared blob needs at least its 4-byte length field.
        if n_blobs as u64 > (body_len - blobs_start) / 4 {
            return Err(Error::format("container declares more blobs than fit"));
        }

        // Trailer CRC — recomputed over the body in chunks when asked.
        file.seek(SeekFrom::Start(body_len))?;
        let mut tail = [0u8; 4];
        file.read_exact(&mut tail)?;
        let stored_crc = u32::from_le_bytes(tail);
        if verify_body {
            file.seek(SeekFrom::Start(0))?;
            let mut crc = crate::util::crc32::Crc32::new();
            let mut remaining = body_len;
            let mut buf = vec![0u8; 1 << 18];
            while remaining > 0 {
                let n = remaining.min(buf.len() as u64) as usize;
                file.read_exact(&mut buf[..n])?;
                crc.update(&buf[..n]);
                remaining -= n as u64;
            }
            if crc.finalize() != stored_crc {
                return Err(Error::format("container CRC mismatch (corrupt file)"));
            }
        }
        Ok(Self { file, header, file_len, n_blobs, blobs_start, stored_crc, prefix_crc })
    }

    /// Parsed container header.
    pub fn header(&self) -> &Json {
        &self.header
    }

    /// Total file size in bytes.
    pub fn file_len(&self) -> u64 {
        self.file_len
    }

    /// Blob count declared by the framing.
    pub fn n_blobs(&self) -> u32 {
        self.n_blobs
    }

    /// Offset of the first blob's length field.
    pub fn blobs_start(&self) -> u64 {
        self.blobs_start
    }

    /// Offset one past the last blob byte (where the trailer CRC begins).
    pub fn body_end(&self) -> u64 {
        self.file_len - 4
    }

    /// The trailer CRC-32 value — what the chain manifest records per
    /// container (verified against the body by [`Self::open`], taken on
    /// trust by [`Self::open_streaming`] until the caller finishes its own
    /// sequential pass — see [`Self::prefix_crc`]).
    pub fn stored_crc(&self) -> u32 {
        self.stored_crc
    }

    /// Running CRC state over the prefix bytes `[0, blobs_start)`. A
    /// reader that consumes the remaining body **in file order** (all
    /// framed blobs, then the trailing blob) can fold those bytes onto a
    /// clone of this state and compare `finalize()` against
    /// [`Self::stored_crc`] — whole-file integrity (header included) in
    /// the same single pass, which is how
    /// [`crate::codec::sharded::decode_streaming`] verifies containers
    /// opened with [`Self::open_streaming`].
    pub fn prefix_crc(&self) -> crate::util::crc32::Crc32 {
        self.prefix_crc.clone()
    }

    /// Read `count` consecutive framed blobs starting at file `offset`;
    /// returns the blob payloads and the offset one past the run.
    pub fn read_blobs_at(&mut self, offset: u64, count: usize) -> Result<(Vec<Vec<u8>>, u64)> {
        use std::io::{Read, Seek, SeekFrom};
        let body_end = self.body_end();
        if offset < self.blobs_start || offset > body_end {
            return Err(Error::format("blob offset outside the container body"));
        }
        self.file.seek(SeekFrom::Start(offset))?;
        let mut pos = offset;
        let mut blobs = Vec::with_capacity(count);
        for _ in 0..count {
            if pos + 4 > body_end {
                return Err(Error::format("container truncated in blob"));
            }
            let mut b4 = [0u8; 4];
            self.file.read_exact(&mut b4)?;
            let len = u32::from_le_bytes(b4) as u64;
            if pos + 4 + len > body_end {
                return Err(Error::format("container truncated in blob"));
            }
            let mut blob = vec![0u8; len as usize];
            self.file.read_exact(&mut blob)?;
            blobs.push(blob);
            pos += 4 + len;
        }
        Ok((blobs, pos))
    }
}

/// Pack a center table (sorted f32s) as bytes.
pub fn centers_to_bytes(centers: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 + centers.len() * 4);
    out.extend_from_slice(&(centers.len() as u16).to_le_bytes());
    for &c in centers {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out
}

/// Parse a center table.
pub fn centers_from_bytes(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() < 2 {
        return Err(Error::format("centers blob too short"));
    }
    let n = u16::from_le_bytes([bytes[0], bytes[1]]) as usize;
    if bytes.len() != 2 + n * 4 {
        return Err(Error::format("centers blob length mismatch"));
    }
    Ok(bytes[2..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Container {
        let mut c = Container::new(Json::obj(vec![
            ("step", Json::num(5000)),
            ("mode", Json::str("lstm")),
        ]));
        c.push_blob(vec![1, 2, 3]);
        c.push_blob(vec![]);
        c.push_blob(vec![0xFF; 100]);
        c
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let bytes = c.to_bytes();
        let back = Container::from_bytes(&bytes).unwrap();
        assert_eq!(back, c);
        assert_eq!(bytes.len(), c.size_bytes());
    }

    #[test]
    fn stored_crc_matches_trailer() {
        let bytes = sample().to_bytes();
        let crc = Container::stored_crc(&bytes).unwrap();
        assert_eq!(crc, crate::util::crc32::hash(&bytes[..bytes.len() - 4]));
        assert!(Container::stored_crc(&bytes[..6]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Container::stored_crc(&bad).is_err());
    }

    #[test]
    fn crc_detects_corruption() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().to_bytes();
        for cut in [5, 12, bytes.len() - 5] {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn missing_blob_index() {
        let c = sample();
        assert!(c.blob(2).is_ok());
        assert!(c.blob(3).is_err());
    }

    #[test]
    fn stream_writer_matches_to_bytes() {
        let c = sample();
        let expect = c.to_bytes();
        let mut sink = Vec::new();
        let mut w =
            ContainerStreamWriter::new(&mut sink, &c.header, c.blobs.len() as u32).unwrap();
        let mut offsets = Vec::new();
        for b in &c.blobs {
            offsets.push(w.offset());
            w.push_blob(b).unwrap();
        }
        let total = w.finish().unwrap();
        assert_eq!(sink, expect);
        assert_eq!(total as usize, expect.len());
        // Reported offsets point at each blob's length field.
        for (i, &off) in offsets.iter().enumerate() {
            let off = off as usize;
            let len = u32::from_le_bytes(sink[off..off + 4].try_into().unwrap()) as usize;
            assert_eq!(len, c.blobs[i].len());
            assert_eq!(&sink[off + 4..off + 4 + len], c.blobs[i].as_slice());
        }
    }

    #[test]
    fn stream_writer_enforces_blob_count() {
        let c = sample();
        let mut sink = Vec::new();
        let w = ContainerStreamWriter::new(&mut sink, &c.header, 2).unwrap();
        // Too few blobs.
        assert!(w.finish().is_err());
        let mut sink = Vec::new();
        let mut w = ContainerStreamWriter::new(&mut sink, &c.header, 1).unwrap();
        w.push_blob(&[1]).unwrap();
        // Too many blobs.
        assert!(w.push_blob(&[2]).is_err());
    }

    #[test]
    fn forged_blob_count_cannot_drive_allocation() {
        // Craft a container whose n_blobs field claims u32::MAX blobs with
        // almost no body behind it; the parser must reject it up front
        // (the CRC is made valid so the count check itself is exercised).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(b"{}");
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let crc = crate::util::crc32::hash(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        let err = Container::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("blobs"), "{err}");
    }

    #[test]
    fn file_reader_serves_framed_blob_runs() {
        let dir = std::env::temp_dir().join(format!("cpcm_creader_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let c = sample();
        let bytes = c.to_bytes();
        let path = dir.join("c.cpcm");
        std::fs::write(&path, &bytes).unwrap();

        let mut r = ContainerFileReader::open(&path).unwrap();
        assert_eq!(r.header(), &c.header);
        assert_eq!(r.n_blobs(), 3);
        assert_eq!(r.file_len() as usize, bytes.len());
        assert_eq!(r.stored_crc(), Container::stored_crc(&bytes).unwrap());
        let start = r.blobs_start();
        let (blobs, end) = r.read_blobs_at(start, 3).unwrap();
        assert_eq!(blobs, c.blobs);
        assert_eq!(end, r.body_end());
        // Partial runs and re-reads work (seek-based).
        let (one, mid) = r.read_blobs_at(start, 1).unwrap();
        assert_eq!(one[0], c.blobs[0]);
        let (rest, end2) = r.read_blobs_at(mid, 2).unwrap();
        assert_eq!(rest, c.blobs[1..]);
        assert_eq!(end2, end);
        // Out-of-body offsets and over-long runs fail cleanly.
        assert!(r.read_blobs_at(0, 1).is_err());
        assert!(r.read_blobs_at(start, 4).is_err());

        // Corruption anywhere fails the chunked CRC at open.
        let mut bad = bytes.clone();
        let mid_byte = bad.len() / 2;
        bad[mid_byte] ^= 0x10;
        std::fs::write(dir.join("bad.cpcm"), &bad).unwrap();
        assert!(ContainerFileReader::open(dir.join("bad.cpcm")).is_err());
        std::fs::write(dir.join("cut.cpcm"), &bytes[..bytes.len() - 7]).unwrap();
        assert!(ContainerFileReader::open(dir.join("cut.cpcm")).is_err());

        // open_streaming skips the body CRC pass (shard readers verify
        // per-shard CRCs instead) but still validates magic + framing and
        // exposes the trailer value for manifest comparison.
        let mut rs = ContainerFileReader::open_streaming(&path).unwrap();
        assert_eq!(rs.stored_crc(), Container::stored_crc(&bytes).unwrap());
        assert_eq!(rs.read_blobs_at(rs.blobs_start(), 3).unwrap().0, c.blobs);
        // Lazy open: mid-body truncation surfaces at read time, not open.
        let mut cut = ContainerFileReader::open_streaming(dir.join("cut.cpcm")).unwrap();
        let start = cut.blobs_start();
        assert!(cut.read_blobs_at(start, 3).is_err());
        let mut not = bytes.clone();
        not[0] = b'X';
        std::fs::write(dir.join("not.cpcm"), &not).unwrap();
        assert!(ContainerFileReader::open_streaming(dir.join("not.cpcm")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn centers_roundtrip() {
        let cs = vec![-1.5f32, 0.0, 2.25, 1e-7];
        let bytes = centers_to_bytes(&cs);
        assert_eq!(centers_from_bytes(&bytes).unwrap(), cs);
        let empty = centers_to_bytes(&[]);
        assert_eq!(centers_from_bytes(&empty).unwrap(), Vec::<f32>::new());
        assert!(centers_from_bytes(&bytes[..5]).is_err());
    }
}

//! Property-testing mini-framework.
//!
//! `proptest` is not available in the offline registry, so this module
//! provides the subset the test suite needs: run a property over many
//! PCG64-seeded random cases, and on failure report the failing case index
//! and seed so it can be replayed deterministically.
//!
//! ```no_run
//! use cpcm::util::prop::{forall, Gen};
//! forall("addition commutes", 256, |g| {
//!     let a = g.i32_range(-1000, 1000);
//!     let b = g.i32_range(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Per-case generator handed to properties; wraps a deterministic PRNG with
/// convenience samplers.
pub struct Gen {
    rng: Pcg64,
    /// Case index, exposed so properties can scale sizes with progress
    /// (small cases first — poor man's shrinking).
    pub case: usize,
    pub cases: usize,
}

impl Gen {
    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below_usize(hi - lo + 1)
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    pub fn i32_range(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi as i64 - lo as i64 + 1) as u64) as i32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    /// Standard normal f32.
    pub fn normal(&mut self) -> f32 {
        self.rng.normal_f32()
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.f64() < p
    }

    /// A size that grows with the case index — early cases are small, which
    /// makes failures easier to read (approximate shrinking).
    pub fn size(&mut self, max: usize) -> usize {
        let cap = ((self.case + 1) * max) / self.cases.max(1);
        self.usize_range(0, cap.max(1).min(max))
    }

    /// Vector of f32 drawn from a mixture resembling pruned residuals:
    /// mostly zeros plus gaussian spikes — the worst case for the codec.
    pub fn sparse_residuals(&mut self, n: usize, sparsity: f64, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|_| if self.bool(sparsity) { 0.0 } else { self.normal() * scale })
            .collect()
    }

    /// Vector of symbols below `alphabet`.
    pub fn symbols(&mut self, n: usize, alphabet: u16) -> Vec<u16> {
        (0..n).map(|_| self.rng.below(alphabet as u64) as u16).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` deterministic random cases. Panics (failing the
/// enclosing test) with the case index and seed on first failure.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    // Base seed derived from the property name so different properties do
    // not share streams but remain reproducible run-to-run.
    let base = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Pcg64::new(seed, 0xa11ce), case, cases };
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed (for debugging).
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen { rng: Pcg64::new(seed, 0xa11ce), case: 0, cases: 1 };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 64, |g| {
            let n = g.usize_range(0, 100);
            assert!(n <= 100);
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn forall_reports_failure() {
        forall("must fail", 16, |g| {
            let n = g.usize_range(0, 100);
            assert!(n < 5, "n too big: {n}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall("det", 8, |g| first.push(g.usize_range(0, 1_000_000)));
        let mut second = Vec::new();
        forall("det", 8, |g| second.push(g.usize_range(0, 1_000_000)));
        assert_eq!(first, second);
    }

    #[test]
    fn sparse_residuals_respect_sparsity() {
        forall("sparsity", 8, |g| {
            let xs = g.sparse_residuals(4000, 0.9, 0.01);
            let zeros = xs.iter().filter(|&&x| x == 0.0).count();
            assert!(zeros > 3200, "zeros={zeros}");
        });
    }
}

//! Durable atomic file replacement: temp sibling → write → fsync →
//! rename → fsync(parent dir).
//!
//! Every path in the crate that publishes a *final* file (containers,
//! the chain manifest, raw checkpoint stores, restore outputs) must
//! route through this module — `tests` greps the durability-critical
//! sources for raw `fs::write`/`fs::rename` calls to enforce it.  The
//! contract:
//!
//! 1. bytes land in a same-directory temp file named
//!    `.tmp.<final-name>` (same filesystem, so the rename is atomic);
//! 2. the temp file is `sync_all`'d — its contents are on stable
//!    storage *before* the final name can ever point at them;
//! 3. the temp is renamed onto the final name (atomic replace);
//! 4. the parent directory is `sync_all`'d so the rename itself (the
//!    directory entry) survives power loss.
//!
//! A crash before step 3 leaves at most a `.tmp.*` orphan, which
//! [`sweep_temps`] removes on the next open; a crash after step 3
//! leaves the complete new file. No observer ever sees a torn final
//! file. All four steps consult [`crate::util::fault`] so the crash
//! matrix can simulate dying at each of them.

use crate::error::Result;
use crate::util::fault;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Prefix shared by every temp file this module creates. Kept as a
/// single definition so sweepers and writers cannot drift apart.
pub const TMP_PREFIX: &str = ".tmp";

/// The temp sibling for `final_path`: `.tmp.<file-name>` in the same
/// directory (same filesystem ⇒ `rename` is atomic).
pub fn tmp_path(final_path: &Path) -> PathBuf {
    let name = final_path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "unnamed".to_string());
    final_path.with_file_name(format!("{TMP_PREFIX}.{name}"))
}

/// Write `bytes` to `path` durably and atomically (see module docs).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path(path);
    write_tmp(&tmp, bytes)?;
    commit(&tmp, path)
}

/// Write `bytes` to the temp file `tmp` (no sync — [`commit`] syncs).
/// Fault hook: a torn write persists half the buffer then errors (the
/// stale temp stays behind, as after a real crash); a bit flip persists
/// a corrupted buffer and reports success.
fn write_tmp(tmp: &Path, bytes: &[u8]) -> Result<()> {
    match fault::on_write(tmp) {
        fault::WriteCheck::Proceed => fs::write(tmp, bytes)?,
        fault::WriteCheck::Fail => return Err(fault::injected("write", tmp).into()),
        fault::WriteCheck::Torn => {
            let mut f = fs::File::create(tmp)?;
            f.write_all(&bytes[..bytes.len() / 2])?;
            let _ = f.sync_all();
            return Err(fault::injected("torn write", tmp).into());
        }
        fault::WriteCheck::BitFlip => {
            let mut corrupted = bytes.to_vec();
            if !corrupted.is_empty() {
                let mid = corrupted.len() / 2;
                corrupted[mid] ^= 0x10;
            }
            fs::write(tmp, corrupted)?;
        }
    }
    Ok(())
}

/// Publish an already-written temp file: fsync it, rename it onto
/// `final_path`, fsync the parent directory. Streaming writers that
/// build their temp file incrementally (e.g. the checkpoint store) call
/// this directly instead of [`write_atomic`].
pub fn commit(tmp: &Path, final_path: &Path) -> Result<()> {
    sync_file(tmp)?;
    rename(tmp, final_path)?;
    sync_parent_dir(final_path)
}

/// Durable rename for files that are already synced (streaming restore
/// moving a finished output into place): rename + parent-dir fsync.
pub fn rename_durable(from: &Path, to: &Path) -> Result<()> {
    sync_file(from)?;
    rename(from, to)?;
    sync_parent_dir(to)
}

fn rename(from: &Path, to: &Path) -> Result<()> {
    fault::on_rename(to)?;
    fs::rename(from, to)?;
    Ok(())
}

/// `sync_all` on `path` (fault-hooked).
pub fn sync_file(path: &Path) -> Result<()> {
    fault::on_sync(path)?;
    fs::File::open(path)?.sync_all()?;
    Ok(())
}

/// `sync_all` on the directory containing `path`, making a completed
/// rename durable. On platforms where directories cannot be opened for
/// sync (non-unix), this is a no-op beyond the fault hook.
pub fn sync_parent_dir(path: &Path) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    fault::on_sync(dir)?;
    #[cfg(unix)]
    fs::File::open(dir)?.sync_all()?;
    Ok(())
}

/// Remove every `.tmp*` file directly inside `dir` — leftovers of
/// writes that crashed before their rename. Returns the removed paths.
/// Matches the legacy `.tmp_*` spelling as well as [`TMP_PREFIX`]`.`.
pub fn sweep_temps(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut removed = Vec::new();
    if !dir.is_dir() {
        return Ok(removed);
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with(TMP_PREFIX) && entry.file_type()?.is_file() {
            fs::remove_file(entry.path())?;
            removed.push(entry.path());
        }
    }
    removed.sort();
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fault::{arm, disarm, FaultMode, FaultOp, FaultPlan};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cpcm_fsatomic_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_roundtrip_and_replace() {
        let d = tmpdir("rt");
        let p = d.join("file.bin");
        write_atomic(&p, b"first").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"first");
        write_atomic(&p, b"second, longer").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"second, longer");
        // No temp residue after a clean commit.
        assert!(sweep_temps(&d).unwrap().is_empty());
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn torn_write_leaves_temp_and_keeps_old_final() {
        let _g = crate::util::fault::tests::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let d = tmpdir("torn");
        let p = d.join("file.bin");
        write_atomic(&p, b"stable contents").unwrap();
        arm(FaultPlan { op: FaultOp::Write, mode: FaultMode::Torn, nth: 1, path_filter: None });
        let err = write_atomic(&p, b"replacement-bytes").unwrap_err();
        assert!(disarm());
        assert!(err.to_string().contains("injected fault"));
        // Old final file untouched; half-written temp left behind.
        assert_eq!(fs::read(&p).unwrap(), b"stable contents");
        let tmp = tmp_path(&p);
        assert!(tmp.exists());
        assert_eq!(fs::read(&tmp).unwrap().len(), b"replacement-bytes".len() / 2);
        // The sweep removes it and nothing else.
        let removed = sweep_temps(&d).unwrap();
        assert_eq!(removed, vec![tmp.clone()]);
        assert!(!tmp.exists());
        assert!(p.exists());
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn failed_rename_keeps_old_final() {
        let _g = crate::util::fault::tests::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let d = tmpdir("ren");
        let p = d.join("file.bin");
        write_atomic(&p, b"old").unwrap();
        arm(FaultPlan { op: FaultOp::Rename, mode: FaultMode::Fail, nth: 1, path_filter: None });
        assert!(write_atomic(&p, b"new").is_err());
        assert!(disarm());
        assert_eq!(fs::read(&p).unwrap(), b"old");
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn bit_flip_reports_success_but_corrupts() {
        let _g = crate::util::fault::tests::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let d = tmpdir("flip");
        let p = d.join("file.bin");
        arm(FaultPlan { op: FaultOp::Write, mode: FaultMode::BitFlip, nth: 1, path_filter: None });
        write_atomic(&p, b"payload-bytes").unwrap();
        assert!(disarm());
        let got = fs::read(&p).unwrap();
        assert_eq!(got.len(), b"payload-bytes".len());
        assert_ne!(got, b"payload-bytes");
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn sweep_ignores_real_files_and_legacy_temps_match() {
        let d = tmpdir("sweep");
        fs::write(d.join("ckpt_1.cpcm"), b"x").unwrap();
        fs::write(d.join(".tmp.manifest.json"), b"y").unwrap();
        fs::write(d.join(".tmp_ckpt_5"), b"z").unwrap();
        let removed = sweep_temps(&d).unwrap();
        assert_eq!(removed.len(), 2);
        assert!(d.join("ckpt_1.cpcm").exists());
        assert!(!d.join(".tmp.manifest.json").exists());
        assert!(!d.join(".tmp_ckpt_5").exists());
        fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn durability_critical_sources_route_through_fs_atomic() {
        // Regression guard for the fsync bugfix: the three paths named
        // in the issue must not hand-roll final-file writes or renames.
        // (`fs::write`/`fs::rename` may only appear in this module.)
        for (name, src) in [
            ("coordinator/mod.rs", include_str!("../coordinator/mod.rs")),
            ("coordinator/manifest.rs", include_str!("../coordinator/manifest.rs")),
            ("coordinator/lifecycle.rs", include_str!("../coordinator/lifecycle.rs")),
            ("coordinator/scrub.rs", include_str!("../coordinator/scrub.rs")),
            ("checkpoint/store.rs", include_str!("../checkpoint/store.rs")),
        ] {
            // Only non-test code is held to the contract (tests plant
            // corruption with raw writes on purpose).
            let prod = src.split("#[cfg(test)]").next().unwrap();
            for forbidden in ["fs::write(", "fs::rename("] {
                assert!(
                    !prod.contains(forbidden),
                    "{name} calls {forbidden}…) directly; route it through util::fs_atomic"
                );
            }
        }
    }
}

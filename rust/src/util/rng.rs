//! PCG64 pseudo-random number generator.
//!
//! Used everywhere reproducible randomness is needed: synthetic training
//! corpora, k-means++ seeding, property tests. Implements the PCG XSL RR
//! 128/64 variant (O'Neill, 2014) — deterministic across platforms.

/// PCG XSL RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (uses two uniforms; no caching so the
    /// stream position is deterministic per call).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if the total mass is zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below_usize(weights.len().max(1));
        }
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-distributed value in `[0, n)` with exponent `s` (rejection-free
    /// inverse-CDF over a precomputed table is overkill here; we use the
    /// standard rejection sampler).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // Rejection method (Devroye). Valid for s > 0, s != 1 handled via hw.
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (x).ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let hinv = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                x.exp()
            } else {
                (1.0 + (1.0 - s) * x).powf(1.0 / (1.0 - s))
            }
        };
        let hx0 = h(0.5) - 1.0;
        let hn = h(n as f64 + 0.5);
        loop {
            let u = hx0 + self.f64() * (hn - hx0);
            let x = hinv(u);
            let k = (x + 0.5).floor().max(1.0);
            if u >= h(k + 0.5) - (-(k.ln() * s)).exp() {
                return (k as u64 - 1).min(n - 1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg64::seed(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(4);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_mass() {
        let mut rng = Pcg64::seed(5);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..50 {
            assert_eq!(rng.weighted(&w), 2);
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut rng = Pcg64::seed(6);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            let v = rng.zipf(16, 1.2) as usize;
            assert!(v < 16);
            counts[v] += 1;
        }
        assert!(counts[0] > counts[8] * 2, "counts={counts:?}");
    }
}

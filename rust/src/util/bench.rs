//! Built-in micro/macro benchmark harness.
//!
//! `criterion` is unavailable offline; the `[[bench]]` targets use
//! `harness = false` and this module instead. It provides warmup, multiple
//! timed samples, and median/mean/min reporting, plus a tiny CSV/Markdown
//! table emitter used by the figure-regeneration benches.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elems: Option<u64>,
}

impl Sample {
    /// Throughput in millions of elements per second (if `elems` set).
    pub fn melems_per_sec(&self) -> Option<f64> {
        self.elems.map(|n| n as f64 / self.median.as_secs_f64() / 1e6)
    }
}

/// Benchmark runner with fixed warmup/sample counts.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Default: 3 warmup runs, 10 samples.
    pub fn new() -> Self {
        Self { warmup: 3, samples: 10, results: Vec::new() }
    }

    /// Quick mode for CI-style runs.
    pub fn quick() -> Self {
        Self { warmup: 1, samples: 3, results: Vec::new() }
    }

    /// Time `f`, which should perform one full iteration of the workload.
    /// `elems` is the number of logical elements processed per iteration
    /// (for throughput reporting); pass 0 to skip.
    pub fn run(&mut self, name: &str, elems: u64, mut f: impl FnMut()) -> Sample {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort();
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let min = times[0];
        let s = Sample {
            name: name.to_string(),
            median,
            mean,
            min,
            elems: if elems > 0 { Some(elems) } else { None },
        };
        let thr = s
            .melems_per_sec()
            .map(|t| format!("  {t:10.2} Melem/s"))
            .unwrap_or_default();
        println!(
            "bench {name:<44} median {:>12?}  min {:>12?}{thr}",
            median, min
        );
        self.results.push(s.clone());
        s
    }

    /// All recorded samples.
    pub fn results(&self) -> &[Sample] {
        &self.results
    }
}

/// Current resident-set size of this process in bytes (Linux `VmRSS`).
/// `None` on platforms without `/proc/self/status`.
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_kb("VmRSS:")
}

/// Peak (high-water) resident-set size of this process in bytes (Linux
/// `VmHWM`). The kernel counter is monotone for the process lifetime, so
/// memory tests measure a *delta*: read before and after the section under
/// test and subtract. `None` on platforms without `/proc/self/status`.
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_kb("VmHWM:")
}

/// Parse a `kB` line of `/proc/self/status` into bytes.
fn proc_status_kb(key: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Minimal table printer for figure benches: rows of (label, values).
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((label.into(), values));
    }

    /// Print as a Markdown table (goes into EXPERIMENTS.md) and echo a CSV
    /// block for plotting.
    pub fn print(&self) {
        println!("\n### {}\n", self.title);
        print!("| |");
        for c in &self.columns {
            print!(" {c} |");
        }
        println!();
        print!("|---|");
        for _ in &self.columns {
            print!("---|");
        }
        println!();
        for (label, vals) in &self.rows {
            print!("| {label} |");
            for v in vals {
                if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.01) {
                    print!(" {v:.3e} |");
                } else {
                    print!(" {v:.4} |");
                }
            }
            println!();
        }
        println!("\ncsv,{}", self.columns.join(","));
        for (label, vals) in &self.rows {
            let vs: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
            println!("csv,{label},{}", vs.join(","));
        }
        println!();
    }

    /// Serialize rows as CSV text (used to append results to files).
    pub fn to_csv(&self) -> String {
        let mut out = format!("label,{}\n", self.columns.join(","));
        for (label, vals) in &self.rows {
            let vs: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
            out.push_str(&format!("{label},{}\n", vs.join(",")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::quick();
        let s = b.run("noop", 100, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.min <= s.median);
        assert_eq!(b.results().len(), 1);
        assert!(s.melems_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn table_csv() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row("r1", vec![1.0, 2.0]);
        let csv = t.to_csv();
        assert!(csv.contains("label,a,b"));
        assert!(csv.contains("r1,1,2"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row("r1", vec![1.0]);
    }

    #[test]
    fn rss_probe_is_sane_where_available() {
        // On Linux both gauges exist and peak >= current > 0; elsewhere the
        // probe degrades to None and callers skip.
        match (current_rss_bytes(), peak_rss_bytes()) {
            (Some(cur), Some(peak)) => {
                assert!(cur > 0);
                assert!(peak >= cur, "peak {peak} < current {cur}");
            }
            (None, None) => {}
            other => panic!("probe half-available: {other:?}"),
        }
    }
}

//! Self-contained LZ77 + adaptive-arithmetic byte compressor.
//!
//! Stands in for DEFLATE in the ExCP baseline ([`crate::baselines`]): the
//! offline registry has no `flate2`, so this module provides the same
//! general-purpose "LZ + entropy coder" family with the crate's own range
//! coder ([`crate::ac`]) as the entropy stage. Same interface shape
//! (`compress`/`decompress` over byte slices), deterministic output.
//!
//! Format: `u64 LE` uncompressed length, then one arithmetic stream of
//! tokens. Each token is a flag bit (literal/match) under a [`BitModel`],
//! a literal byte under an order-0 [`AdaptiveModel`], or a match:
//! length−3 under a 128-symbol model (match lengths 3..=130) and a
//! distance coded as an adaptive log₂ bucket plus raw offset bits
//! (window 64 KiB). Matching uses a greedy hash-chain search.

use crate::ac::{AdaptiveModel, BitModel, Decoder, Encoder};
use crate::{Error, Result};

const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 130;
const WINDOW: usize = 65_535;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 32;
/// Sentinel for "no previous position" in the hash chains.
const NIL: u32 = u32::MAX;

#[inline]
fn hash3(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], 0]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Token models, shared (and identically updated) by both directions.
struct Models {
    flag: BitModel,
    lit: AdaptiveModel,
    len: AdaptiveModel,
    dist_slot: AdaptiveModel,
}

impl Models {
    fn new() -> Self {
        Self {
            flag: BitModel::new(),
            lit: AdaptiveModel::new(256),
            len: AdaptiveModel::new(MAX_MATCH - MIN_MATCH + 1),
            dist_slot: AdaptiveModel::new(16),
        }
    }
}

/// Compress `data` (deterministic; empty input allowed).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    out.extend_from_slice(&(n as u64).to_le_bytes());

    let mut m = Models::new();
    let mut enc = Encoder::new();
    let mut head = vec![NIL; 1 << HASH_BITS];
    let mut prev = vec![NIL; n];

    let mut i = 0usize;
    while i < n {
        let (mut best_len, mut best_dist) = (0usize, 0usize);
        if i + MIN_MATCH <= n {
            let h = hash3(data, i);
            let mut cand = head[h];
            let mut chain = 0usize;
            while cand != NIL && chain < MAX_CHAIN {
                let c = cand as usize;
                let dist = i - c;
                if dist > WINDOW {
                    break;
                }
                let max_len = (n - i).min(MAX_MATCH);
                let mut l = 0usize;
                while l < max_len && data[c + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l == max_len {
                        break;
                    }
                }
                cand = prev[c];
                chain += 1;
            }
        }

        if best_len >= MIN_MATCH {
            m.flag.encode(&mut enc, true);
            m.len.encode(&mut enc, (best_len - MIN_MATCH) as u16);
            let slot = 31 - (best_dist as u32).leading_zeros();
            m.dist_slot.encode(&mut enc, slot as u16);
            enc.encode_raw(best_dist as u32 - (1 << slot), slot as u8);
            // Index every covered position so later matches can reach here.
            let end = i + best_len;
            while i < end {
                if i + MIN_MATCH <= n {
                    let h = hash3(data, i);
                    prev[i] = head[h];
                    head[h] = i as u32;
                }
                i += 1;
            }
        } else {
            m.flag.encode(&mut enc, false);
            m.lit.encode(&mut enc, data[i] as u16);
            if i + MIN_MATCH <= n {
                let h = hash3(data, i);
                prev[i] = head[h];
                head[h] = i as u32;
            }
            i += 1;
        }
    }
    out.extend_from_slice(&enc.finish());
    out
}

/// Decompress a [`compress`]-produced buffer.
pub fn decompress(bytes: &[u8]) -> Result<Vec<u8>> {
    if bytes.len() < 8 {
        return Err(Error::codec("lz stream shorter than its length header"));
    }
    let n64 = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let n = usize::try_from(n64)
        .map_err(|_| Error::codec("lz stream length exceeds address space"))?;
    let mut m = Models::new();
    let mut dec = Decoder::new(&bytes[8..])?;
    // The header length is untrusted: cap the preallocation and let the
    // vector grow as real tokens arrive.
    let mut out: Vec<u8> = Vec::with_capacity(n.min(1 << 20));
    while out.len() < n {
        if m.flag.decode(&mut dec) {
            let len = m.len.decode(&mut dec) as usize + MIN_MATCH;
            let slot = m.dist_slot.decode(&mut dec) as u32;
            let dist = ((1u32 << slot) + dec.decode_raw(slot as u8)) as usize;
            if dist == 0 || dist > out.len() || out.len() + len > n {
                return Err(Error::codec("lz stream corrupt (bad match)"));
            }
            let start = out.len() - dist;
            // Byte-by-byte: matches may overlap their own output.
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        } else {
            out.push(m.lit.decode(&mut dec) as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn empty_roundtrip() {
        let c = compress(&[]);
        assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn repetitive_data_compresses_hard() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 7) as u8).collect();
        let c = compress(&data);
        assert!(c.len() < data.len() / 10, "compressed {} of {}", c.len(), data.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn random_data_survives_roundtrip() {
        let mut rng = Pcg64::seed(3);
        let data: Vec<u8> = (0..50_000).map(|_| rng.below(256) as u8).collect();
        let c = compress(&data);
        assert_eq!(decompress(&c).unwrap(), data);
        // Incompressible input must not blow up.
        assert!(c.len() < data.len() + 1024);
    }

    #[test]
    fn truncated_input_rejected() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[1, 2, 3]).is_err());
        // Length header present but arithmetic stream missing.
        assert!(decompress(&[9, 0, 0, 0, 0, 0, 0, 0, 1]).is_err());
    }

    #[test]
    fn overlapping_matches() {
        // "aaaa…" forces dist-1 matches that overlap their own output.
        let data = vec![b'a'; 4000];
        let c = compress(&data);
        assert!(c.len() < 100);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn prop_roundtrip_mixed_content() {
        forall("lz roundtrip", 25, |g| {
            let n = g.size(4000);
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                if g.bool(0.5) && !data.is_empty() {
                    // Repeat a previous span.
                    let start = g.usize_range(0, data.len() - 1);
                    let len = g.usize_range(1, 40).min(data.len() - start);
                    let span: Vec<u8> = data[start..start + len].to_vec();
                    data.extend_from_slice(&span);
                } else {
                    data.push(g.usize_range(0, 255) as u8);
                }
            }
            data.truncate(n);
            let c = compress(&data);
            assert_eq!(decompress(&c).unwrap(), data);
        });
    }
}

//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` polynomial).
//!
//! The offline registry has no `crc32fast`; this is the standard
//! reflected-polynomial table implementation. Byte-for-byte compatible
//! with `crc32fast::hash` (poly 0xEDB88320, init/xorout 0xFFFFFFFF), so
//! containers written before the vendoring swap still CRC-check.

const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (one-shot).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finalize()
}

/// Incremental CRC-32 hasher — same polynomial and init/xorout as
/// [`hash`], so `Crc32` fed the same bytes in any chunking produces the
/// identical value. Used by the streaming container writer, which cannot
/// buffer the whole file to checksum it in one shot.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh hasher.
    pub fn new() -> Self {
        Self { state: !0u32 }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
        }
        self.state = crc;
    }

    /// The CRC-32 of everything updated so far (the hasher stays usable).
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn sensitive_to_any_bit() {
        let a = hash(b"hello world");
        let b = hash(b"hello worle");
        assert_ne!(a, b);
    }

    #[test]
    fn incremental_matches_one_shot_for_any_chunking() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let expect = hash(&data);
        for chunk in [1usize, 3, 7, 256, 999, 1000] {
            let mut h = Crc32::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(h.finalize(), expect, "chunk={chunk}");
        }
        // Empty updates are no-ops; finalize is repeatable.
        let mut h = Crc32::new();
        h.update(b"");
        assert_eq!(h.finalize(), hash(b""));
        assert_eq!(h.finalize(), hash(b""));
    }
}

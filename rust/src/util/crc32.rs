//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` polynomial).
//!
//! The offline registry has no `crc32fast`; this is the standard
//! reflected-polynomial table implementation. Byte-for-byte compatible
//! with `crc32fast::hash` (poly 0xEDB88320, init/xorout 0xFFFFFFFF), so
//! containers written before the vendoring swap still CRC-check.

const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (one-shot).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn sensitive_to_any_bit() {
        let a = hash(b"hello world");
        let b = hash(b"hello worle");
        assert_ne!(a, b);
    }
}

//! Minimal scoped work pool (no external dependencies).
//!
//! [`run_scoped`] executes a batch of heterogeneous-cost tasks on up to
//! `workers` scoped threads and returns the results **in task order**.
//! Workers pull tasks from a shared atomic cursor, so long tasks do not
//! starve short ones behind a static partition. Panics inside a task are
//! caught and surfaced as [`Error`] (carrying the panic message) instead
//! of aborting the process — one poisoned coding lane fails the encode
//! cleanly.
//!
//! Used by the codec's `3 × L` lane fan-out ([`crate::codec`]) and by the
//! coordinator's encode→decode verification ([`crate::coordinator`]).

use crate::{Error, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A unit of work for [`run_scoped`].
pub type Task<'a, T> = Box<dyn FnOnce() -> T + Send + 'a>;

/// Number of hardware threads (≥ 1).
pub fn available_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `tasks` on at most `workers` threads (clamped to the task count;
/// the calling thread counts as one worker, so `workers == 1` runs
/// everything inline without spawning). Returns results in task order, or
/// the first panic as an error.
pub fn run_scoped<'a, T: Send>(workers: usize, tasks: Vec<Task<'a, T>>) -> Result<Vec<T>> {
    let n = tasks.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, n);
    let slots: Vec<Mutex<Option<Task<'a, T>>>> =
        tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<std::thread::Result<T>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 1..workers {
            scope.spawn(|| worker_loop(&next, &slots, &results));
        }
        worker_loop(&next, &slots, &results);
    });

    let mut out = Vec::with_capacity(n);
    for slot in results {
        match slot.into_inner().expect("pool result mutex poisoned") {
            Some(Ok(v)) => out.push(v),
            Some(Err(payload)) => {
                return Err(Error::codec(format!(
                    "worker panicked: {}",
                    panic_message(payload.as_ref())
                )))
            }
            None => return Err(Error::codec("pool task was never executed")),
        }
    }
    Ok(out)
}

fn worker_loop<'a, T: Send>(
    next: &AtomicUsize,
    slots: &[Mutex<Option<Task<'a, T>>>],
    results: &[Mutex<Option<std::thread::Result<T>>>],
) {
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= slots.len() {
            break;
        }
        // Take the task out before running it so the lock is not held
        // across a potential panic.
        let task = slots[i].lock().expect("pool task mutex poisoned").take();
        if let Some(task) = task {
            let outcome = catch_unwind(AssertUnwindSafe(task));
            *results[i].lock().expect("pool result mutex poisoned") = Some(outcome);
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_task_order() {
        let tasks: Vec<Task<usize>> = (0..64)
            .map(|i| {
                let b: Task<usize> = Box::new(move || {
                    // Uneven task cost to shuffle completion order.
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    i * 10
                });
                b
            })
            .collect();
        let out = run_scoped(4, tasks).unwrap();
        assert_eq!(out, (0..64).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_runs_inline() {
        let tasks: Vec<Task<u32>> = (0..5).map(|i| Box::new(move || i) as Task<u32>).collect();
        assert_eq!(run_scoped(1, tasks).unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_task_list() {
        let out: Vec<u8> = run_scoped(8, Vec::new()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn panic_becomes_error_not_abort() {
        let tasks: Vec<Task<u32>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("lane 1 poisoned")),
            Box::new(|| 3),
        ];
        let err = run_scoped(2, tasks).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("worker panicked"), "{msg}");
        assert!(msg.contains("lane 1 poisoned"), "{msg}");
    }

    #[test]
    fn tasks_can_borrow_from_the_caller() {
        let data: Vec<u64> = (0..1000).collect();
        let chunks: Vec<&[u64]> = data.chunks(100).collect();
        let tasks: Vec<Task<u64>> = chunks
            .into_iter()
            .map(|c| Box::new(move || c.iter().sum::<u64>()) as Task<u64>)
            .collect();
        let sums = run_scoped(3, tasks).unwrap();
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }
}

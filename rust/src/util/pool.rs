//! Persistent work pool (no external dependencies).
//!
//! [`run_scoped`] executes a batch of heterogeneous-cost tasks on the
//! process-wide [`PersistentPool`] and returns the results **in task
//! order**. Workers pull tasks from a shared atomic cursor, so long tasks
//! do not starve short ones behind a static partition. Panics inside a
//! task are caught and surfaced as [`Error`] (carrying the panic message)
//! instead of aborting the process — one poisoned coding lane fails the
//! encode cleanly.
//!
//! ## Persistence
//!
//! Pool threads are spawned **once** (lazily, on the first batch) and
//! parked on a condvar between batches, so a high-rate checkpoint stream
//! through [`crate::coordinator`] pays the thread-spawn cost once per
//! process instead of once per encode. The submitting thread always
//! participates in its own batch, so progress never depends on a pool
//! thread being free (or existing at all — a single-core machine runs a
//! zero-thread pool and every batch inline).
//!
//! Multiple threads may submit batches concurrently (the pipelined
//! coordinator overlaps the quantization of checkpoint *k+1* with the
//! entropy coding of checkpoint *k*); batches share the fixed worker set.
//! Results are bit-deterministic regardless of scheduling: a task's output
//! depends only on the task, and [`run_scoped`] reassembles outputs in
//! task order.
//!
//! Thread reuse is observable through [`global_stats`]: `threads_spawned`
//! stays constant across consecutive batches while `jobs` (the batch
//! generation counter) keeps increasing — the coordinator snapshots both
//! into its [`crate::metrics`] registry.
//!
//! ## Nested (sub-batch) submission
//!
//! A task running on a pool worker may itself call [`run_scoped`] on the
//! same pool. This can never deadlock, by construction: a submitter
//! always participates in its own batch, so the inner batch completes
//! even when every other worker is busy, and idle workers *steal into*
//! whichever claimable batch sits in the queue — outer or inner — through
//! the shared task cursor. The shard scheduler ([`crate::codec`]'s
//! `sched` module) leans on this: each format-3 shard task submits its
//! own `3 × lanes` lane sub-batch, so total parallelism reaches
//! `min(shards · 3 · lanes, threads)` without dedicating threads to
//! either level. Panics keep their usual contract under nesting: an inner
//! task's panic surfaces as an [`Error`] to the inner submitter (the
//! outer task), which propagates it as an ordinary task result.
//!
//! Used by the codec's `3 × L` lane fan-out ([`crate::codec`]), the shard
//! scheduler's shard×lane task graph, and the coordinator's
//! encode→decode verification ([`crate::coordinator`]).

use crate::{Error, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A unit of work for [`run_scoped`].
pub type Task<'a, T> = Box<dyn FnOnce() -> T + Send + 'a>;

/// Number of hardware threads (≥ 1).
pub fn available_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Counters describing a pool's lifetime activity (see [`global_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads currently owned by the pool (excludes submitters).
    pub threads: usize,
    /// Total worker threads ever spawned. For a healthy persistent pool
    /// this equals `threads` forever — it increasing between two batches
    /// would mean threads are being re-created per job.
    pub threads_spawned: u64,
    /// Batches executed so far (the pool "generation" counter; inline
    /// single-worker batches count too).
    pub jobs: u64,
}

/// One submitted batch, visible to pool workers.
///
/// `work` is the submitter's batch closure with its lifetime erased to
/// `'static`. Safety: the submitter blocks in `PersistentPool::run_batch`
/// until this entry has `claims_left == 0 && running == 0` and is removed
/// from the queue, so no worker can observe the reference after the
/// closure's stack frame is gone. Claims and completions are both updated
/// under the pool mutex, so revocation cannot race a claim.
struct Batch {
    id: u64,
    work: &'static (dyn Fn() + Sync),
    /// How many more pool workers may still join this batch.
    claims_left: usize,
    /// Pool workers currently executing `work`.
    running: usize,
}

#[derive(Default)]
struct PoolState {
    queue: Vec<Batch>,
    next_id: u64,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Signals workers: a batch is claimable (or shutdown).
    work_cv: Condvar,
    /// Signals submitters: a batch's `running` count dropped.
    done_cv: Condvar,
    threads_spawned: AtomicU64,
    jobs: AtomicU64,
}

/// A fixed-size pool of parked worker threads executing scoped batches.
///
/// Most code should use the free [`run_scoped`], which targets the lazy
/// process-wide instance; owned pools exist for tests and for callers that
/// need deterministic thread teardown — dropping an owned pool drains the
/// queue and joins every worker.
pub struct PersistentPool {
    inner: Arc<PoolInner>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl PersistentPool {
    /// Spawn a pool with `threads` parked workers (0 is valid: every batch
    /// then runs inline on its submitting thread).
    pub fn new(threads: usize) -> Self {
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            threads_spawned: AtomicU64::new(0),
            jobs: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let inner = inner.clone();
            inner.threads_spawned.fetch_add(1, Ordering::Relaxed);
            let handle = std::thread::Builder::new()
                .name(format!("cpcm-pool-{i}"))
                .spawn(move || worker_main(&inner))
                .expect("failed to spawn pool worker");
            handles.push(handle);
        }
        Self { inner, handles: Mutex::new(handles) }
    }

    /// Lifetime counters (thread count, spawn total, batch total).
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            threads: self.handles.lock().expect("pool handles poisoned").len(),
            threads_spawned: self.inner.threads_spawned.load(Ordering::Relaxed),
            jobs: self.inner.jobs.load(Ordering::Relaxed),
        }
    }

    /// Run `tasks` on at most `workers` threads of this pool (clamped to
    /// the task count; the calling thread counts as one worker, so
    /// `workers == 1` runs everything inline without touching the pool).
    /// Returns results in task order, or the first panic as an error.
    pub fn run_scoped<'a, T: Send>(
        &self,
        workers: usize,
        tasks: Vec<Task<'a, T>>,
    ) -> Result<Vec<T>> {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = workers.clamp(1, n);
        let slots: Vec<Mutex<Option<Task<'a, T>>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<std::thread::Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);

        self.inner.jobs.fetch_add(1, Ordering::Relaxed);
        {
            let work = || worker_loop(&next, &slots, &results);
            self.run_batch(workers - 1, &work);
        }

        let mut out = Vec::with_capacity(n);
        for slot in results {
            match slot.into_inner().expect("pool result mutex poisoned") {
                Some(Ok(v)) => out.push(v),
                Some(Err(payload)) => {
                    return Err(Error::codec(format!(
                        "worker panicked: {}",
                        panic_message(payload.as_ref())
                    )))
                }
                None => return Err(Error::codec("pool task was never executed")),
            }
        }
        Ok(out)
    }

    /// Execute `work` on the calling thread plus up to `helpers` pool
    /// workers, returning only when every worker that entered `work` has
    /// left it (so `work` may borrow from the caller's stack).
    fn run_batch(&self, helpers: usize, work: &(dyn Fn() + Sync)) {
        if helpers == 0 {
            work();
            return;
        }
        // SAFETY: `work` outlives this call, and this function does not
        // return until the batch entry has been removed from the queue
        // with no worker running it (see `Batch` docs).
        let work_static: &'static (dyn Fn() + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(work)
        };
        let id;
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            id = st.next_id;
            st.next_id += 1;
            st.queue.push(Batch { id, work: work_static, claims_left: helpers, running: 0 });
        }
        self.inner.work_cv.notify_all();

        // The guard — not straight-line code — performs the revoke-and-wait
        // cleanup, so it runs even if `work` unwinds on this thread; the
        // batch entry must never outlive this frame (it borrows it).
        let _guard = BatchGuard { inner: &self.inner, id };

        // Participate in our own batch. On return (or unwind) all tasks
        // have been *claimed*, but helpers may still be finishing their
        // last one; `_guard` revokes the unclaimed helper slots and waits
        // the stragglers out before the borrowed frame dies.
        work();
    }

    /// Ask workers to exit once the queue drains, then join them all.
    /// Called by `Drop`; idempotent.
    pub fn shutdown(&self) {
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        let mut handles = self.handles.lock().expect("pool handles poisoned");
        for handle in handles.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for PersistentPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Unwind-safe completion of one submitted batch: on drop, revoke the
/// batch's unclaimed helper slots and block until no worker is still
/// inside its closure, then remove the queue entry. Runs on the normal
/// path *and* when the submitter's own `work()` panics — without it, an
/// unwinding submitter would leave workers a dangling reference into its
/// freed stack frame. Uses poison-tolerant locking: aborting via a second
/// panic inside drop would skip the cleanup this guard exists for.
struct BatchGuard<'a> {
    inner: &'a PoolInner,
    id: u64,
}

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            let Some(pos) = st.queue.iter().position(|b| b.id == self.id) else {
                return;
            };
            st.queue[pos].claims_left = 0;
            if st.queue[pos].running == 0 {
                st.queue.remove(pos);
                return;
            }
            st = match self.inner.done_cv.wait(st) {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

fn worker_main(inner: &PoolInner) {
    let mut st = inner.state.lock().expect("pool state poisoned");
    loop {
        if let Some(pos) = st.queue.iter().position(|b| b.claims_left > 0) {
            let batch = &mut st.queue[pos];
            batch.claims_left -= 1;
            batch.running += 1;
            let id = batch.id;
            let work = batch.work;
            drop(st);
            // Task panics are already caught inside `worker_loop`; this
            // guard only ensures the `running` count is restored if the
            // batch closure itself unwinds (e.g. a poisoned task mutex).
            let _ = catch_unwind(AssertUnwindSafe(work));
            st = inner.state.lock().expect("pool state poisoned");
            if let Some(b) = st.queue.iter_mut().find(|b| b.id == id) {
                b.running -= 1;
            }
            inner.done_cv.notify_all();
        } else if st.shutdown {
            return;
        } else {
            st = inner.work_cv.wait(st).expect("pool state poisoned");
        }
    }
}

/// The process-wide pool: `available_workers() - 1` parked threads
/// (submitters always participate in their own batches, so total
/// parallelism is the hardware thread count).
pub fn global() -> &'static PersistentPool {
    &**global_cell()
}

/// A clonable handle to the process-wide pool, for components that thread
/// an explicit pool through their layers (e.g. the codec and the
/// coordinator's encode stage) instead of reaching for the global — tests
/// can substitute an owned pool through the same seam.
pub fn global_handle() -> Arc<PersistentPool> {
    global_cell().clone()
}

fn global_cell() -> &'static Arc<PersistentPool> {
    static GLOBAL: OnceLock<Arc<PersistentPool>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(PersistentPool::new(available_workers().saturating_sub(1))))
}

/// Lifetime counters of the process-wide pool (metrics surface).
pub fn global_stats() -> PoolStats {
    global().stats()
}

/// Run `tasks` on at most `workers` threads of the process-wide
/// persistent pool (clamped to the task count; the calling thread counts
/// as one worker, so `workers == 1` runs everything inline). Returns
/// results in task order, or the first panic as an error.
pub fn run_scoped<'a, T: Send>(workers: usize, tasks: Vec<Task<'a, T>>) -> Result<Vec<T>> {
    global().run_scoped(workers, tasks)
}

fn worker_loop<'a, T: Send>(
    next: &AtomicUsize,
    slots: &[Mutex<Option<Task<'a, T>>>],
    results: &[Mutex<Option<std::thread::Result<T>>>],
) {
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= slots.len() {
            break;
        }
        // Take the task out before running it so the lock is not held
        // across a potential panic.
        let task = slots[i].lock().expect("pool task mutex poisoned").take();
        if let Some(task) = task {
            let outcome = catch_unwind(AssertUnwindSafe(task));
            *results[i].lock().expect("pool result mutex poisoned") = Some(outcome);
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_task_order() {
        let tasks: Vec<Task<usize>> = (0..64)
            .map(|i| {
                let b: Task<usize> = Box::new(move || {
                    // Uneven task cost to shuffle completion order.
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    i * 10
                });
                b
            })
            .collect();
        let out = run_scoped(4, tasks).unwrap();
        assert_eq!(out, (0..64).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_runs_inline() {
        let tasks: Vec<Task<u32>> = (0..5).map(|i| Box::new(move || i) as Task<u32>).collect();
        assert_eq!(run_scoped(1, tasks).unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_task_list() {
        let out: Vec<u8> = run_scoped(8, Vec::new()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn panic_becomes_error_not_abort() {
        let tasks: Vec<Task<u32>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("lane 1 poisoned")),
            Box::new(|| 3),
        ];
        let err = run_scoped(2, tasks).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("worker panicked"), "{msg}");
        assert!(msg.contains("lane 1 poisoned"), "{msg}");
    }

    #[test]
    fn tasks_can_borrow_from_the_caller() {
        let data: Vec<u64> = (0..1000).collect();
        let chunks: Vec<&[u64]> = data.chunks(100).collect();
        let tasks: Vec<Task<u64>> = chunks
            .into_iter()
            .map(|c| Box::new(move || c.iter().sum::<u64>()) as Task<u64>)
            .collect();
        let sums = run_scoped(3, tasks).unwrap();
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn global_pool_reuses_threads_across_batches() {
        // Warm the pool, then check the spawn counter stays flat while
        // the job counter advances — the persistence acceptance check.
        let mk = |n: usize| -> Vec<Task<usize>> {
            (0..n).map(|i| Box::new(move || i) as Task<usize>).collect()
        };
        run_scoped(8, mk(16)).unwrap();
        let s0 = global_stats();
        run_scoped(8, mk(16)).unwrap();
        let s1 = global_stats();
        run_scoped(8, mk(16)).unwrap();
        let s2 = global_stats();
        assert_eq!(s0.threads_spawned, s1.threads_spawned);
        assert_eq!(s1.threads_spawned, s2.threads_spawned);
        assert_eq!(s1.threads_spawned, s1.threads as u64);
        assert!(s1.jobs > s0.jobs, "{s1:?} vs {s0:?}");
        assert!(s2.jobs > s1.jobs, "{s2:?} vs {s1:?}");
    }

    #[test]
    fn owned_pool_drop_joins_workers() {
        let pool = PersistentPool::new(3);
        let tasks: Vec<Task<u32>> = (0..10).map(|i| Box::new(move || i) as Task<u32>).collect();
        let out = pool.run_scoped(4, tasks).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(pool.stats().threads, 3);
        pool.shutdown();
        assert_eq!(pool.stats().threads, 0);
        // Drop after explicit shutdown is a no-op (idempotent).
        drop(pool);
    }

    #[test]
    fn zero_thread_pool_runs_inline() {
        let pool = PersistentPool::new(0);
        let tasks: Vec<Task<u32>> = (0..6).map(|i| Box::new(move || i * 2) as Task<u32>).collect();
        assert_eq!(pool.run_scoped(4, tasks).unwrap(), vec![0, 2, 4, 6, 8, 10]);
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let pool = Arc::new(PersistentPool::new(2));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let pool = pool.clone();
            joins.push(std::thread::spawn(move || {
                for round in 0..8u64 {
                    let tasks: Vec<Task<u64>> = (0..16)
                        .map(|i| Box::new(move || t * 1000 + round * 100 + i) as Task<u64>)
                        .collect();
                    let out = pool.run_scoped(3, tasks).unwrap();
                    let expect: Vec<u64> =
                        (0..16).map(|i| t * 1000 + round * 100 + i).collect();
                    assert_eq!(out, expect);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn nested_submission_completes_without_deadlock() {
        // A task running on a pool worker submits its own sub-batch on
        // the SAME pool (the shard→lane shape): the submitter drives its
        // inner batch itself, so this terminates even on a tiny pool.
        let pool = Arc::new(PersistentPool::new(1));
        let outer: Vec<Task<u64>> = (0..6u64)
            .map(|i| {
                let pool = pool.clone();
                let b: Task<u64> = Box::new(move || {
                    let inner: Vec<Task<u64>> =
                        (0..8u64).map(|j| Box::new(move || i * 100 + j) as Task<u64>).collect();
                    pool.run_scoped(4, inner).unwrap().into_iter().sum()
                });
                b
            })
            .collect();
        let sums = pool.run_scoped(3, outer).unwrap();
        let expect: Vec<u64> = (0..6u64).map(|i| (0..8u64).map(|j| i * 100 + j).sum()).collect();
        assert_eq!(sums, expect);
    }

    #[test]
    fn nested_submission_under_saturated_pipeline() {
        // Several concurrent submitters (the pipelined coordinator shape)
        // each run outer batches whose tasks nest sub-batches, all sharing
        // a pool smaller than the submitter count. Must terminate with
        // correct, ordered results.
        let pool = Arc::new(PersistentPool::new(2));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let pool = pool.clone();
            joins.push(std::thread::spawn(move || {
                for round in 0..4u64 {
                    let outer: Vec<Task<u64>> = (0..4u64)
                        .map(|i| {
                            let pool = pool.clone();
                            let b: Task<u64> = Box::new(move || {
                                let inner: Vec<Task<u64>> = (0..6u64)
                                    .map(|j| {
                                        Box::new(move || t * 10_000 + round * 1000 + i * 10 + j)
                                            as Task<u64>
                                    })
                                    .collect();
                                pool.run_scoped(8, inner).unwrap().into_iter().sum()
                            });
                            b
                        })
                        .collect();
                    let got = pool.run_scoped(3, outer).unwrap();
                    let expect: Vec<u64> = (0..4u64)
                        .map(|i| (0..6u64).map(|j| t * 10_000 + round * 1000 + i * 10 + j).sum())
                        .collect();
                    assert_eq!(got, expect);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn nested_panic_surfaces_as_error_not_deadlock() {
        // A panic in an inner sub-batch becomes an Error at the inner
        // submitter (the outer task), which can propagate it as a normal
        // result; the pool stays usable afterwards.
        let pool = Arc::new(PersistentPool::new(2));
        let outer: Vec<Task<std::result::Result<u64, String>>> = (0..3u64)
            .map(|i| {
                let pool = pool.clone();
                let b: Task<std::result::Result<u64, String>> = Box::new(move || {
                    let inner: Vec<Task<u64>> = (0..4u64)
                        .map(|j| {
                            let b: Task<u64> = Box::new(move || {
                                if i == 1 && j == 2 {
                                    panic!("inner lane poisoned");
                                }
                                j
                            });
                            b
                        })
                        .collect();
                    pool.run_scoped(4, inner)
                        .map(|v| v.into_iter().sum())
                        .map_err(|e| format!("{e}"))
                });
                b
            })
            .collect();
        let results = pool.run_scoped(3, outer).unwrap();
        assert_eq!(results[0], Ok(6));
        assert_eq!(results[2], Ok(6));
        let err = results[1].as_ref().unwrap_err();
        assert!(err.contains("inner lane poisoned"), "{err}");
        // Pool still works.
        let tasks: Vec<Task<u32>> = (0..4).map(|i| Box::new(move || i) as Task<u32>).collect();
        assert_eq!(pool.run_scoped(3, tasks).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn panicking_batch_does_not_wedge_the_pool() {
        // A panic in one batch must leave the pool usable for the next.
        let pool = PersistentPool::new(2);
        let tasks: Vec<Task<u32>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| 2), Box::new(|| 3)];
        assert!(pool.run_scoped(3, tasks).is_err());
        let tasks: Vec<Task<u32>> = (0..4).map(|i| Box::new(move || i) as Task<u32>).collect();
        assert_eq!(pool.run_scoped(3, tasks).unwrap(), vec![0, 1, 2, 3]);
    }
}

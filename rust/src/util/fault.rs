//! Process-global fault-injection plan for durability testing.
//!
//! The crash-point matrix test (`tests/crash_matrix.rs`) needs to
//! simulate a process dying at *every* point in the durable-write
//! sequence: mid `write`, before a `rename`, before an `fsync`.  All
//! durable filesystem operations in the crate route through
//! [`crate::util::fs_atomic`], which consults the plan armed here before
//! each operation.
//!
//! A plan fires exactly once: the Nth matching operation trips it, the
//! configured failure is injected, and subsequent operations proceed
//! normally (the caller is expected to treat the injected error as a
//! crash and abandon the run).  When no plan is armed the only cost on
//! the I/O path is one relaxed atomic load.
//!
//! This module is compiled unconditionally (not `#[cfg(test)]`) because
//! integration tests live in a separate crate and could not arm a
//! test-only hook; it injects nothing unless [`arm`] has been called.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Which durable filesystem operation a plan matches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    /// Writing bytes to a temp file (`fs_atomic::write_atomic`).
    Write,
    /// The atomic rename of a temp file onto its final name.
    Rename,
    /// An `fsync` of a file or parent directory.
    Sync,
    /// Any of the above — used by the crash matrix to enumerate every
    /// sequence point with a single counter.
    Any,
}

/// What happens when the plan trips.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// The operation fails outright; nothing (new) reaches the disk.
    Fail,
    /// A write persists only the first half of the buffer, then fails —
    /// models a torn write at power loss. On `Rename`/`Sync` this
    /// degrades to [`FaultMode::Fail`].
    Torn,
    /// The write completes and *reports success* but one bit of the
    /// buffer is flipped — models silent media corruption. Only
    /// meaningful for `Write`; degrades to [`FaultMode::Fail`] elsewhere.
    BitFlip,
}

/// An armed fault: trip on the `nth` (1-based) operation matching `op`
/// whose path contains `path_filter` (no filter = every path matches).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub op: FaultOp,
    pub mode: FaultMode,
    pub nth: u64,
    pub path_filter: Option<String>,
}

struct State {
    plan: Option<FaultPlan>,
    /// Matching operations seen since [`arm`].
    seen: u64,
    /// Whether the armed plan has fired.
    tripped: bool,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<State> = Mutex::new(State { plan: None, seen: 0, tripped: false });

/// Arm `plan`. Replaces any previously armed plan and resets counters.
pub fn arm(plan: FaultPlan) {
    let mut st = STATE.lock().unwrap();
    st.plan = Some(plan);
    st.seen = 0;
    st.tripped = false;
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm and report whether the plan fired. Clears all state; callers
/// that only want to peek without clearing should use [`tripped`].
pub fn disarm() -> bool {
    let mut st = STATE.lock().unwrap();
    let was = st.tripped;
    st.plan = None;
    st.seen = 0;
    st.tripped = false;
    ARMED.store(false, Ordering::SeqCst);
    was
}

/// Whether the currently / last armed plan has fired.
pub fn tripped() -> bool {
    STATE.lock().unwrap().tripped
}

/// Decision returned to `fs_atomic` for a write about to happen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum WriteCheck {
    /// No fault: perform the write normally.
    Proceed,
    /// Persist only the first half of the buffer, then report failure.
    Torn,
    /// Persist the buffer with one bit flipped and report success.
    BitFlip,
    /// Fail without writing anything.
    Fail,
}

fn check(op: FaultOp, path: &std::path::Path) -> Option<FaultMode> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut st = STATE.lock().unwrap();
    let plan = st.plan.as_ref()?;
    if plan.op != FaultOp::Any && plan.op != op {
        return None;
    }
    if let Some(f) = &plan.path_filter {
        if !path.to_string_lossy().contains(f.as_str()) {
            return None;
        }
    }
    st.seen += 1;
    if st.seen != st.plan.as_ref().unwrap().nth {
        return None;
    }
    st.tripped = true;
    let mode = st.plan.as_ref().unwrap().mode;
    // One-shot: later operations in the (doomed) process proceed.
    st.plan = None;
    ARMED.store(false, Ordering::SeqCst);
    Some(mode)
}

/// Consult the plan before writing `path`.
pub(crate) fn on_write(path: &std::path::Path) -> WriteCheck {
    match check(FaultOp::Write, path) {
        None => WriteCheck::Proceed,
        Some(FaultMode::Fail) => WriteCheck::Fail,
        Some(FaultMode::Torn) => WriteCheck::Torn,
        Some(FaultMode::BitFlip) => WriteCheck::BitFlip,
    }
}

/// Consult the plan before renaming `path`; `Err` means "crash here".
pub(crate) fn on_rename(path: &std::path::Path) -> std::io::Result<()> {
    match check(FaultOp::Rename, path) {
        None => Ok(()),
        Some(_) => Err(injected("rename", path)),
    }
}

/// Consult the plan before fsyncing `path`; `Err` means "crash here".
pub(crate) fn on_sync(path: &std::path::Path) -> std::io::Result<()> {
    match check(FaultOp::Sync, path) {
        None => Ok(()),
        Some(_) => Err(injected("sync", path)),
    }
}

/// The error all injected faults surface as.
pub(crate) fn injected(op: &str, path: &std::path::Path) -> std::io::Error {
    std::io::Error::other(format!("injected fault: {op} of {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    // Fault state is process-global; unit tests here and the fs_atomic
    // ones share this lock so they cannot interleave arms.
    pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn nth_op_trips_once_with_path_filter() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        arm(FaultPlan {
            op: FaultOp::Write,
            mode: FaultMode::Fail,
            nth: 2,
            path_filter: Some("ckpt_".into()),
        });
        // Non-matching path and op are not counted.
        assert_eq!(on_write(Path::new("/d/manifest.json")), WriteCheck::Proceed);
        assert!(on_rename(Path::new("/d/ckpt_1.cpcm")).is_ok());
        assert_eq!(on_write(Path::new("/d/ckpt_1.cpcm")), WriteCheck::Proceed);
        assert!(!tripped());
        assert_eq!(on_write(Path::new("/d/ckpt_2.cpcm")), WriteCheck::Fail);
        assert!(tripped());
        // One-shot: the plan is spent.
        assert_eq!(on_write(Path::new("/d/ckpt_3.cpcm")), WriteCheck::Proceed);
        assert!(disarm());
        assert!(!disarm());
    }

    #[test]
    fn any_matches_all_ops_and_modes_map() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        arm(FaultPlan { op: FaultOp::Any, mode: FaultMode::Torn, nth: 3, path_filter: None });
        assert!(on_sync(Path::new("/a")).is_ok());
        assert!(on_rename(Path::new("/b")).is_ok());
        // Third matching op is a sync: Torn degrades to a plain failure.
        assert!(on_sync(Path::new("/c")).is_err());
        assert!(disarm());

        arm(FaultPlan { op: FaultOp::Write, mode: FaultMode::BitFlip, nth: 1, path_filter: None });
        assert_eq!(on_write(Path::new("/x")), WriteCheck::BitFlip);
        assert!(disarm());
    }

    #[test]
    fn disarmed_is_inert() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disarm();
        for _ in 0..4 {
            assert_eq!(on_write(Path::new("/p")), WriteCheck::Proceed);
            assert!(on_rename(Path::new("/p")).is_ok());
            assert!(on_sync(Path::new("/p")).is_ok());
        }
        assert!(!tripped());
    }
}

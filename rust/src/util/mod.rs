//! Self-contained utility substrates.
//!
//! The offline build environment has no `serde`, `rand`, `proptest`,
//! `criterion`, `crc32fast` or `flate2`, so this module provides the
//! minimal, well-tested equivalents the rest of the crate needs: a JSON
//! parser/writer ([`json`]), a PCG64 PRNG ([`rng`]), bit-level I/O
//! ([`bitio`]), CRC-32 ([`crc32`]), an LZ77+range-coder byte compressor
//! ([`lz`]), descriptive statistics ([`stats`]), a property-testing
//! mini-framework ([`prop`]), a bench harness ([`bench`]), a persistent
//! work pool ([`pool`]), a bounded backpressure queue ([`queue`]),
//! durable atomic file replacement ([`fs_atomic`]) and the
//! fault-injection plan that tests it ([`fault`]).

pub mod bench;
pub mod bitio;
pub mod crc32;
pub mod fault;
pub mod fs_atomic;
pub mod json;
pub mod lz;
pub mod pool;
pub mod prop;
pub mod queue;
pub mod rng;
pub mod stats;

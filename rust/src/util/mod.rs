//! Self-contained utility substrates.
//!
//! The offline build environment has no `serde`, `rand`, `proptest` or
//! `criterion`, so this module provides the minimal, well-tested equivalents
//! the rest of the crate needs: a JSON parser/writer ([`json`]), a PCG64
//! PRNG ([`rng`]), bit-level I/O ([`bitio`]), descriptive statistics
//! ([`stats`]), a property-testing mini-framework ([`prop`]) and a bench
//! harness ([`bench`]).

pub mod bench;
pub mod bitio;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

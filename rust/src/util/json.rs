//! Minimal JSON parser and writer.
//!
//! `serde` is unavailable in the offline build environment, so the config
//! system, the AOT artifact manifest and the container header use this
//! self-contained implementation. Supports the full JSON grammar (RFC 8259)
//! minus surrogate-pair escapes beyond the BMP edge cases we don't emit.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization
/// (container headers are hashed).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // ---- typed accessors ------------------------------------------------

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64, if an exact non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required field accessors used by manifest/config loaders.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| Error::format(format!("missing field '{key}'")))
    }
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| Error::format(format!("field '{key}' not a string")))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::format(format!("field '{key}' not an integer")))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| Error::format(format!("field '{key}' not a number")))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| Error::format(format!("field '{key}' not an array")))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser accepts. Documents the crate
/// produces nest a handful of levels; untrusted input (manifests,
/// container headers) must not be able to overflow the stack with
/// `[[[[…`, so recursion is capped well below any real stack limit.
pub const MAX_DEPTH: usize = 96;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting level (see [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { at: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.enter()?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("bad \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn depth_is_capped_not_stack_overflowed() {
        // One level under the cap parses…
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        // …one over errors, and a pathological document returns Err
        // instead of exhausting the stack.
        let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(Json::parse(&over).is_err());
        let bomb = "[{\"k\":".repeat(200_000);
        assert!(Json::parse(&bomb).is_err());
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"cpcm","dims":[3,4],"lr":0.001,"flag":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::num(1), Json::num(2)])),
            ("b", Json::obj(vec![("c", Json::str("hi"))])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        // Surrogate pair for U+1F600.
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse(r#"{"a":1} x"#).is_err());
        assert!(Json::parse("01abc").is_err());
    }

    #[test]
    fn integer_accessors() {
        let v = Json::parse(r#"{"n": 42, "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 42);
        assert!(v.req_usize("f").is_err());
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn control_chars_escaped_on_write() {
        let v = Json::Str("a\u{1}b".into());
        let s = v.to_string();
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}

//! Bit-level I/O over byte buffers.
//!
//! Used by the quantizer for packing n-bit symbol indices (the paper packs
//! several int4/int2 values into one int8 at save time) and by parts of the
//! container format. The arithmetic coder has its own byte-oriented
//! renormalization and does not go through this module.

use crate::{Error, Result};

/// MSB-first bit writer into an owned `Vec<u8>`.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits accumulated in `cur`, from the MSB side.
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    /// Create an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value`, MSB first. `n <= 32`.
    #[inline]
    pub fn write_bits(&mut self, value: u32, n: u8) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || value < (1u32 << n), "value {value} does not fit in {n} bits");
        let mut left = n;
        while left > 0 {
            let room = 8 - self.nbits;
            let take = room.min(left);
            let shift = left - take;
            let chunk = ((value >> shift) as u8) & ((1u16 << take) - 1) as u8;
            self.cur |= chunk << (room - take);
            self.nbits += take;
            left -= take;
            if self.nbits == 8 {
                self.buf.push(self.cur);
                self.cur = 0;
                self.nbits = 0;
            }
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u32, 1);
    }

    /// Number of complete bytes written so far (excluding the partial byte).
    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }

    /// Total bits written.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush the partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push(self.cur);
        }
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Bits already consumed from `buf[pos]`.
    consumed: u8,
}

impl<'a> BitReader<'a> {
    /// Create a reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, consumed: 0 }
    }

    /// Read `n` bits MSB-first. Errors on overrun.
    #[inline]
    pub fn read_bits(&mut self, n: u8) -> Result<u32> {
        debug_assert!(n <= 32);
        let mut out: u32 = 0;
        let mut left = n;
        while left > 0 {
            if self.pos >= self.buf.len() {
                return Err(Error::codec("bit reader overrun"));
            }
            let avail = 8 - self.consumed;
            let take = avail.min(left);
            let byte = self.buf[self.pos];
            let chunk = (byte >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | chunk as u32;
            self.consumed += take;
            left -= take;
            if self.consumed == 8 {
                self.consumed = 0;
                self.pos += 1;
            }
        }
        Ok(out)
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> Result<bool> {
        Ok(self.read_bits(1)? != 0)
    }

    /// Total bits consumed.
    pub fn bits_read(&self) -> usize {
        self.pos * 8 + self.consumed as usize
    }
}

/// Pack a slice of symbols, each fitting in `bits` bits, MSB-first.
pub fn pack_symbols(symbols: &[u16], bits: u8) -> Vec<u8> {
    let mut w = BitWriter::new();
    for &s in symbols {
        w.write_bits(s as u32, bits);
    }
    w.finish()
}

/// Unpack `count` symbols of `bits` bits each.
pub fn unpack_symbols(buf: &[u8], bits: u8, count: usize) -> Result<Vec<u16>> {
    let mut r = BitReader::new(buf);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(r.read_bits(bits)? as u16);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_single_bits() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &b in &pattern {
            assert_eq!(r.read_bit().unwrap(), b);
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        let mut rng = Pcg64::seed(11);
        let items: Vec<(u32, u8)> = (0..500)
            .map(|_| {
                let n = 1 + rng.below(24) as u8;
                let v = (rng.next_u64() as u32) & ((1u32 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n).unwrap(), v, "width {n}");
        }
    }

    #[test]
    fn pack_unpack_int4() {
        let syms: Vec<u16> = (0..33).map(|i| (i % 16) as u16).collect();
        let packed = pack_symbols(&syms, 4);
        assert_eq!(packed.len(), 17); // ceil(33*4/8)
        let out = unpack_symbols(&packed, 4, 33).unwrap();
        assert_eq!(out, syms);
    }

    #[test]
    fn pack_unpack_int2() {
        let syms: Vec<u16> = (0..41).map(|i| (i % 4) as u16).collect();
        let packed = pack_symbols(&syms, 2);
        assert_eq!(packed.len(), 11); // ceil(41*2/8)
        assert_eq!(unpack_symbols(&packed, 2, 41).unwrap(), syms);
    }

    #[test]
    fn overrun_is_error() {
        let buf = [0xFFu8];
        let mut r = BitReader::new(&buf);
        assert!(r.read_bits(8).is_ok());
        assert!(r.read_bits(1).is_err());
    }

    #[test]
    fn write_32_bits() {
        let mut w = BitWriter::new();
        w.write_bits(0xDEAD_BEEF, 32);
        let buf = w.finish();
        assert_eq!(buf, vec![0xDE, 0xAD, 0xBE, 0xEF]);
    }
}

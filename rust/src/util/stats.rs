//! Descriptive statistics used by the pruning thresholds (paper Eq. 4–5
//! need `median(W)` and `mean(v_t)`) and by the Fig.-1 correlation analysis.

/// Arithmetic mean. Returns 0 for empty input.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Mean of absolute values.
pub fn mean_abs(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| (x as f64).abs()).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m) * (x as f64 - m)).sum::<f64>() / xs.len() as f64
}

/// Standard deviation.
pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// Median of absolute values via quickselect (O(n) expected, no full sort).
/// This is the `median(W)` term of the paper's Eq. 4 threshold, which ExCP
/// computes over |W|.
pub fn median_abs(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let mid = v.len() / 2;
    let (_, m, _) = v.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
    let hi = *m as f64;
    if v.len() % 2 == 1 {
        hi
    } else {
        // Even length: average of the two middle elements. After
        // select_nth the lower part contains all elements <= v[mid]; its
        // max is the other middle element.
        let lo = v[..mid].iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        (lo + hi) / 2.0
    }
}

/// Quantile in [0,1] by nearest-rank on a sorted copy.
pub fn quantile(xs: &[f32], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((q * (v.len() - 1) as f64).round() as usize).min(v.len() - 1);
    v[idx] as f64
}

/// Pearson correlation coefficient between two equally-sized samples.
/// Returns 0 when either side has zero variance.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let ma = mean(a);
    let mb = mean(b);
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        let dx = x as f64 - ma;
        let dy = y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Shannon entropy (bits/symbol) of a discrete symbol stream with the given
/// alphabet size — the lower bound an order-0 coder can reach; used in tests
/// and EXPERIMENTS.md to sanity-check coder efficiency.
pub fn entropy_bits(symbols: &[u16], alphabet: usize) -> f64 {
    if symbols.is_empty() {
        return 0.0;
    }
    let mut counts = vec![0u64; alphabet];
    for &s in symbols {
        counts[s as usize] += 1;
    }
    let n = symbols.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Fraction of zero symbols — sparsity after pruning.
pub fn sparsity(symbols: &[u16]) -> f64 {
    if symbols.is_empty() {
        return 0.0;
    }
    symbols.iter().filter(|&&s| s == 0).count() as f64 / symbols.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0f32, -2.0, 3.0, -4.0, 5.0];
        assert!((mean(&xs) - 0.6).abs() < 1e-9);
        assert!((median_abs(&xs) - 3.0).abs() < 1e-9);
        assert!((mean_abs(&xs) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn median_even_length() {
        let xs = [1.0f32, 2.0, 3.0, 10.0];
        assert!((median_abs(&xs) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn median_matches_sort_reference() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seed(9);
        for n in [1usize, 2, 3, 10, 101, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let mut sorted: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let expect = if n % 2 == 1 {
                sorted[n / 2] as f64
            } else {
                (sorted[n / 2 - 1] as f64 + sorted[n / 2] as f64) / 2.0
            };
            assert!((median_abs(&xs) - expect).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [-2.0f32, -4.0, -6.0, -8.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_zero_variance() {
        let a = [1.0f32, 1.0, 1.0];
        let b = [1.0f32, 2.0, 3.0];
        assert_eq!(pearson(&a, &b), 0.0);
    }

    #[test]
    fn entropy_uniform_and_constant() {
        let uniform: Vec<u16> = (0..1024).map(|i| (i % 16) as u16).collect();
        assert!((entropy_bits(&uniform, 16) - 4.0).abs() < 1e-9);
        let constant = vec![3u16; 100];
        assert_eq!(entropy_bits(&constant, 16), 0.0);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let s = [0u16, 0, 1, 2, 0, 3];
        assert!((sparsity(&s) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_basics() {
        let xs = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }
}

//! Bounded MPMC queue with blocking *and* rejecting producers.
//!
//! The coordinator's pipeline stages are connected by [`BoundedQueue`]s:
//! a fixed capacity gives **backpressure** (a fast trainer blocks in
//! `submit` instead of buffering unbounded multi-hundred-MB checkpoints),
//! while [`BoundedQueue::try_push`] lets latency-sensitive producers shed
//! load instead of stalling. Unlike `std::sync::mpsc::sync_channel`, the
//! queue exposes its current depth ([`BoundedQueue::len`]) so the
//! coordinator can publish per-stage queue-depth gauges.
//!
//! Closing ([`BoundedQueue::close`]) is cooperative shutdown: producers
//! get their item back ([`PushError::Closed`]), consumers drain whatever
//! is left and then see `None`. Clones share the same queue.
//!
//! **Poisoning.** A thread that panics while holding the queue's mutex
//! poisons it. The queue *recovers* instead of propagating the panic:
//! the poisoned guard is taken back and the queue is marked closed, so
//! one crashed stage degrades to the documented shutdown behavior —
//! producers get [`PushError::Closed`], consumers drain and see `None` —
//! rather than turning every later `submit`/`pop`/`close` into a panic
//! cascade. (The coordinator's panic→`Error` contract depends on this:
//! a stage panic must surface once as a stage error, not re-panic in
//! every thread that touches a shared queue afterwards.)

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Outcome of a failed [`BoundedQueue::try_push`] / [`BoundedQueue::push`],
/// returning the rejected item to the caller.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue is at capacity (only returned by `try_push`).
    Full(T),
    /// Queue was closed; no more items will be accepted.
    Closed(T),
}

struct Shared<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> Shared<T> {
    /// Take the state lock, recovering from poisoning (see module docs):
    /// a panic under the lock degrades the queue to closed instead of
    /// cascading panics through every later caller.
    fn lock(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(|e| self.recover(e))
    }

    /// Reclaim a poisoned guard and force the closed state. Waiters are
    /// woken so blocked producers/consumers observe the shutdown.
    fn recover<'a>(
        &'a self,
        e: PoisonError<MutexGuard<'a, State<T>>>,
    ) -> MutexGuard<'a, State<T>> {
        let mut st = e.into_inner();
        if !st.closed {
            st.closed = true;
            self.not_full.notify_all();
            self.not_empty.notify_all();
        }
        st
    }
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded blocking FIFO shared by cloning.
pub struct BoundedQueue<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        Self { shared: self.shared.clone() }
    }
}

impl<T> BoundedQueue<T> {
    /// New queue holding at most `capacity` items (min 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            shared: Arc::new(Shared {
                state: Mutex::new(State { items: VecDeque::new(), closed: false }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                capacity: capacity.max(1),
            }),
        }
    }

    /// Capacity the queue was built with.
    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Items currently queued (racy by nature; for gauges/diagnostics).
    pub fn len(&self) -> usize {
        self.shared.lock().items.len()
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push: waits while the queue is full. Fails only when the
    /// queue has been closed, handing the item back.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.shared.lock();
        loop {
            if st.closed {
                return Err(PushError::Closed(item));
            }
            if st.items.len() < self.shared.capacity {
                st.items.push_back(item);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = match self.shared.not_full.wait(st) {
                Ok(g) => g,
                Err(e) => self.shared.recover(e),
            };
        }
    }

    /// Non-blocking push: rejects with [`PushError::Full`] instead of
    /// waiting when the queue is at capacity.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.shared.lock();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.shared.capacity {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for an item; returns `None` once the queue is
    /// closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.shared.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = match self.shared.not_empty.wait(st) {
                Ok(g) => g,
                Err(e) => self.shared.recover(e),
            };
        }
    }

    /// Close the queue: producers start failing, consumers drain what is
    /// left. Idempotent.
    pub fn close(&self) {
        let mut st = self.shared.lock();
        st.closed = true;
        drop(st);
        self.shared.not_full.notify_all();
        self.shared.not_empty.notify_all();
    }

    /// True once [`BoundedQueue::close`] has been called (or the queue
    /// degraded to closed after a panic poisoned its lock).
    pub fn is_closed(&self) -> bool {
        self.shared.lock().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn try_push_rejects_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_rejects_producers_and_drains_consumers() {
        let q = BoundedQueue::new(4);
        q.push("a").unwrap();
        q.push("b").unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.push("c"), Err(PushError::Closed("c")));
        assert_eq!(q.try_push("d"), Err(PushError::Closed("d")));
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_push_waits_for_capacity() {
        let q = BoundedQueue::new(1);
        q.push(10u32).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(20u32));
        // Give the producer time to block on the full queue.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop(), Some(10));
        producer.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(20));
    }

    #[test]
    fn pop_blocks_until_item_or_close() {
        let q: BoundedQueue<u8> = BoundedQueue::new(2);
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(7).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(7));
        let q3 = q.clone();
        let consumer = std::thread::spawn(move || q3.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn capacity_is_at_least_one() {
        let q: BoundedQueue<u8> = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.push(1).unwrap();
        assert_eq!(q.try_push(2), Err(PushError::Full(2)));
    }

    #[test]
    fn poisoned_producer_degrades_to_closed_queue() {
        let q = BoundedQueue::new(4);
        q.push(1u32).unwrap();
        q.push(2u32).unwrap();

        // A producer that panics while holding the state mutex: this
        // poisons the lock, which used to turn every later queue call
        // into an `.expect("queue poisoned")` panic cascade.
        let q2 = q.clone();
        let crashed = std::thread::spawn(move || {
            let _guard = q2.shared.state.lock().unwrap();
            panic!("stage crashed while holding the queue lock");
        });
        assert!(crashed.join().is_err());

        // Producers see the documented shutdown contract, not a panic.
        assert_eq!(q.push(3), Err(PushError::Closed(3)));
        assert_eq!(q.try_push(4), Err(PushError::Closed(4)));
        assert!(q.is_closed());

        // Consumers drain what was queued before the crash, then None.
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);

        // Idempotent close still works on the recovered queue.
        q.close();
        assert!(q.is_closed());
    }

    #[test]
    fn poisoning_wakes_blocked_consumer() {
        let q: BoundedQueue<u8> = BoundedQueue::new(2);
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));

        let q3 = q.clone();
        let crashed = std::thread::spawn(move || {
            let _guard = q3.shared.state.lock().unwrap();
            panic!("poison while a consumer waits");
        });
        assert!(crashed.join().is_err());

        // The blocked consumer must observe the degraded-to-closed state
        // (recover() notifies both condvars) instead of hanging. A later
        // len() call also recovers the lock, so nudge via any queue op.
        assert!(q.is_closed());
        assert_eq!(consumer.join().unwrap(), None);
    }
}

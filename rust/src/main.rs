//! cpcm CLI entrypoint.
fn main() {
    if let Err(e) = cpcm::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

//! PJRT-backed probability model: executes the AOT JAX/Pallas programs.
//!
//! Uses the `lstm_*_init` / `lstm_*_probs` / `lstm_*_train` programs from
//! the artifact manifest (see `python/compile/aot.py`). Parameters and
//! Adam state live as [`HostTensor`]s and round-trip through the runtime
//! thread on every call; the AOT batch size is fixed, so smaller batches
//! are zero-padded and the padding rows' outputs discarded (padding also
//! enters `update`, with padded targets fixed to symbol 0 — both encoder
//! and decoder do this identically, preserving determinism).

use super::{LstmCfg, ProbModel};
use crate::runtime::{HostTensor, RuntimeHandle};
use crate::{Error, Result};

/// JAX/Pallas LSTM over the PJRT runtime thread.
pub struct PjrtLstm {
    cfg: LstmCfg,
    rt: RuntimeHandle,
    probs_prog: String,
    train_prog: String,
    /// Flat params, then Adam m and v (same order as the manifest spec).
    params: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
    step: f32,
}

impl PjrtLstm {
    /// Instantiate via the config's `lstm_*_init` program.
    pub fn new(rt: RuntimeHandle, cfg: LstmCfg) -> Result<Self> {
        let prefix = cfg.program_prefix();
        let init_prog = format!("{prefix}_init");
        let params = rt.run(&init_prog, vec![HostTensor::scalar_i32(cfg.seed as i32)])?;
        let m: Vec<HostTensor> = params.iter().map(HostTensor::zeros_like).collect();
        let v = m.clone();
        Ok(Self {
            cfg,
            rt,
            probs_prog: format!("{prefix}_probs"),
            train_prog: format!("{prefix}_train"),
            params,
            m,
            v,
            step: 0.0,
        })
    }

    /// Pad a `rows × seq` context buffer up to the AOT batch size.
    fn pad_contexts(&self, contexts: &[i32], rows: usize) -> Vec<i32> {
        let want = self.cfg.batch * self.cfg.seq;
        let mut out = Vec::with_capacity(want);
        out.extend_from_slice(contexts);
        out.resize(want, 0);
        debug_assert!(rows <= self.cfg.batch);
        out
    }
}

impl ProbModel for PjrtLstm {
    fn cfg(&self) -> &LstmCfg {
        &self.cfg
    }

    fn probs(&mut self, contexts: &[i32]) -> Result<Vec<f32>> {
        let seq = self.cfg.seq;
        if contexts.is_empty() || contexts.len() % seq != 0 {
            return Err(Error::shape("context buffer not a multiple of seq"));
        }
        let rows = contexts.len() / seq;
        if rows > self.cfg.batch {
            return Err(Error::shape(format!(
                "batch {rows} exceeds AOT batch {}",
                self.cfg.batch
            )));
        }
        let padded = self.pad_contexts(contexts, rows);
        let tokens = HostTensor::i32(vec![self.cfg.batch, seq], padded)?;
        let mut args = self.params.clone();
        args.push(tokens);
        let out = self.rt.run(&self.probs_prog, args)?;
        let all = out
            .into_iter()
            .next()
            .ok_or_else(|| Error::Xla("probs program returned nothing".into()))?
            .into_f32s()?;
        Ok(all[..rows * self.cfg.alphabet].to_vec())
    }

    fn update(&mut self, contexts: &[i32], targets: &[u16]) -> Result<f32> {
        let seq = self.cfg.seq;
        if contexts.is_empty() || contexts.len() % seq != 0 {
            return Err(Error::shape("context buffer not a multiple of seq"));
        }
        let rows = contexts.len() / seq;
        if targets.len() != rows {
            return Err(Error::shape("targets length != batch rows"));
        }
        let padded = self.pad_contexts(contexts, rows);
        let mut tgt: Vec<i32> = targets.iter().map(|&t| t as i32).collect();
        tgt.resize(self.cfg.batch, 0);

        self.step += 1.0;
        let n = self.params.len();
        let mut args = Vec::with_capacity(3 * n + 3);
        args.extend(self.params.iter().cloned());
        args.extend(self.m.iter().cloned());
        args.extend(self.v.iter().cloned());
        args.push(HostTensor::scalar_f32(self.step));
        args.push(HostTensor::i32(vec![self.cfg.batch, seq], padded)?);
        args.push(HostTensor::i32(vec![self.cfg.batch], tgt)?);
        let mut out = self.rt.run(&self.train_prog, args)?;
        if out.len() != 3 * n + 1 {
            return Err(Error::Xla(format!(
                "train program returned {} outputs, want {}",
                out.len(),
                3 * n + 1
            )));
        }
        let loss = out.pop().unwrap().f32s()?[0];
        self.v = out.split_off(2 * n);
        self.m = out.split_off(n);
        self.params = out;
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn handle() -> Option<RuntimeHandle> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(RuntimeHandle::spawn(dir).unwrap())
    }

    #[test]
    fn probs_and_update_roundtrip() {
        let Some(rt) = handle() else { return };
        let cfg = LstmCfg::tiny();
        let mut model = PjrtLstm::new(rt, cfg.clone()).unwrap();
        let ctx: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % 16) as i32).collect();
        let probs = model.probs(&ctx).unwrap();
        assert_eq!(probs.len(), cfg.batch * cfg.alphabet);
        for row in probs.chunks(cfg.alphabet) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        }
        let targets = vec![3u16; cfg.batch];
        let l1 = model.update(&ctx, &targets).unwrap();
        let mut l_last = l1;
        for _ in 0..10 {
            l_last = model.update(&ctx, &targets).unwrap();
        }
        assert!(l_last < l1, "loss did not drop: {l1} → {l_last}");
    }

    #[test]
    fn partial_batch_padding() {
        let Some(rt) = handle() else { return };
        let cfg = LstmCfg::tiny();
        let mut model = PjrtLstm::new(rt, cfg.clone()).unwrap();
        // 5 rows out of 32.
        let ctx = vec![1i32; 5 * cfg.seq];
        let probs = model.probs(&ctx).unwrap();
        assert_eq!(probs.len(), 5 * cfg.alphabet);
        let loss = model.update(&ctx, &[0, 1, 2, 3, 4]).unwrap();
        assert!(loss.is_finite());
        // Oversized batch rejected.
        let big = vec![0i32; (cfg.batch + 1) * cfg.seq];
        assert!(model.probs(&big).is_err());
    }

    #[test]
    fn deterministic_replay_across_instances() {
        // The decode-side contract: a fresh model replaying the same call
        // sequence produces identical probabilities.
        let Some(rt) = handle() else { return };
        let cfg = LstmCfg::tiny();
        let mut a = PjrtLstm::new(rt.clone(), cfg.clone()).unwrap();
        let mut b = PjrtLstm::new(rt, cfg.clone()).unwrap();
        let ctx: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| ((i * 7) % 16) as i32).collect();
        let tgt: Vec<u16> = (0..cfg.batch).map(|i| (i % 16) as u16).collect();
        for _ in 0..3 {
            let pa = a.probs(&ctx).unwrap();
            let pb = b.probs(&ctx).unwrap();
            assert_eq!(pa, pb);
            let la = a.update(&ctx, &tgt).unwrap();
            let lb = b.update(&ctx, &tgt).unwrap();
            assert_eq!(la, lb);
        }
    }
}

//! Bayesian mixture of the LSTM and an adaptive order-0 expert.
//!
//! Extension over the paper (its future-work direction of stronger
//! probability modeling, cf. CMIX-style context mixing): the coding
//! distribution is `w·p_lstm + (1−w)·p_order0`, with `w` updated after
//! every batch by exponentiated-gradient / Bayes weighting on each
//! expert's batch log-loss (forgetting factor for non-stationarity).
//!
//! Properties:
//! - deterministic and decoder-symmetric (weights depend only on coded
//!   symbols and contexts);
//! - the mixture's asymptotic code length is within the mixing regret of
//!   the *better* expert, so the codec can no longer lose badly to plain
//!   adaptive AC while the LSTM is still warming up — the failure mode
//!   measured in EXPERIMENTS.md §Tuning.

use super::{LstmCfg, ProbModel};
use crate::Result;

/// Mixture wrapper implementing [`ProbModel`].
pub struct MixModel {
    lstm: Box<dyn ProbModel>,
    /// Order-0 expert: adaptive frequencies (mirrors `ac::AdaptiveModel`).
    freqs: Vec<u32>,
    total: u32,
    increment: u32,
    /// Log-weights of (lstm, order0), kept normalized max=0.
    log_w: [f64; 2],
    /// Per-call scratch of the last blended probabilities' components is
    /// not kept: update() recomputes the LSTM's view, costing one extra
    /// forward per batch (~15%) in exchange for statelessness.
    cfg: LstmCfg,
}

/// Forgetting factor on the expert log-weights (non-stationary streams).
const FORGET: f64 = 0.98;
/// Weight floor so a temporarily bad expert can recover.
const W_FLOOR: f64 = 1e-3;

impl MixModel {
    /// Wrap an LSTM-backend model.
    pub fn new(lstm: Box<dyn ProbModel>) -> Self {
        let cfg = lstm.cfg().clone();
        let a = cfg.alphabet;
        Self {
            lstm,
            freqs: vec![1; a],
            total: a as u32,
            increment: 32,
            log_w: [0.0, 0.0],
            cfg,
        }
    }

    fn weights(&self) -> (f32, f32) {
        let m = self.log_w[0].max(self.log_w[1]);
        let e0 = (self.log_w[0] - m).exp();
        let e1 = (self.log_w[1] - m).exp();
        let w = (e0 / (e0 + e1)).clamp(W_FLOOR, 1.0 - W_FLOOR);
        (w as f32, 1.0 - w as f32)
    }

    fn order0_probs(&self) -> Vec<f32> {
        let inv = 1.0 / self.total as f32;
        self.freqs.iter().map(|&f| f as f32 * inv).collect()
    }

    fn update_counts(&mut self, sym: u16) {
        self.freqs[sym as usize] += self.increment;
        self.total += self.increment;
        if self.total >= crate::ac::MAX_TOTAL {
            self.total = 0;
            for f in &mut self.freqs {
                *f = (*f + 1) >> 1;
                self.total += *f;
            }
        }
    }

    fn blend(&self, lstm_probs: &[f32], rows: usize) -> Vec<f32> {
        let a = self.cfg.alphabet;
        let p0 = self.order0_probs();
        let (wl, w0) = self.weights();
        let mut out = vec![0.0f32; rows * a];
        for r in 0..rows {
            for s in 0..a {
                out[r * a + s] = wl * lstm_probs[r * a + s] + w0 * p0[s];
            }
        }
        out
    }
}

impl ProbModel for MixModel {
    fn cfg(&self) -> &LstmCfg {
        &self.cfg
    }

    fn probs(&mut self, contexts: &[i32]) -> Result<Vec<f32>> {
        let rows = contexts.len() / self.cfg.seq;
        let lp = self.lstm.probs(contexts)?;
        Ok(self.blend(&lp, rows))
    }

    fn update(&mut self, contexts: &[i32], targets: &[u16]) -> Result<f32> {
        let rows = targets.len();
        let a = self.cfg.alphabet;
        // Expert losses on this batch (before adaptation).
        let lp = self.lstm.probs(contexts)?;
        let p0 = self.order0_probs();
        let mut loss_l = 0.0f64;
        let mut loss_0 = 0.0f64;
        for (r, &t) in targets.iter().enumerate() {
            loss_l -= (lp[r * a + t as usize].max(1e-12) as f64).ln();
            loss_0 -= (p0[t as usize].max(1e-12) as f64).ln();
        }
        loss_l /= rows as f64;
        loss_0 /= rows as f64;
        // Bayes/EG weight update with forgetting.
        self.log_w[0] = FORGET * self.log_w[0] - loss_l;
        self.log_w[1] = FORGET * self.log_w[1] - loss_0;
        // Renormalize to keep magnitudes bounded.
        let m = self.log_w[0].max(self.log_w[1]);
        self.log_w[0] -= m;
        self.log_w[1] -= m;
        // Adapt both experts.
        let lstm_loss = self.lstm.update(contexts, targets)?;
        for &t in targets {
            self.update_counts(t);
        }
        Ok(lstm_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::{Cdf, Decoder, Encoder};
    use crate::lstm::Backend;
    use crate::util::rng::Pcg64;

    fn cfg() -> LstmCfg {
        LstmCfg { alphabet: 8, seq: 4, embed: 8, hidden: 8, batch: 16, ..Default::default() }
    }

    fn make() -> MixModel {
        MixModel::new(Backend::Native.make(&cfg()).unwrap())
    }

    #[test]
    fn probs_are_distributions() {
        let mut m = make();
        let ctx = vec![0i32; 16 * 4];
        let p = m.probs(&ctx).unwrap();
        for row in p.chunks(8) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s}");
        }
    }

    #[test]
    fn deterministic_replay() {
        let mut a = make();
        let mut b = make();
        let mut rng = Pcg64::seed(3);
        for _ in 0..5 {
            let ctx: Vec<i32> = (0..16 * 4).map(|_| rng.below(8) as i32).collect();
            let tgt: Vec<u16> = (0..16).map(|_| rng.below(8) as u16).collect();
            assert_eq!(a.probs(&ctx).unwrap(), b.probs(&ctx).unwrap());
            assert_eq!(a.update(&ctx, &tgt).unwrap(), b.update(&ctx, &tgt).unwrap());
        }
    }

    #[test]
    fn tracks_order0_on_skewed_random_stream() {
        // Contexts carry no signal; symbols heavily skewed. The mixture
        // must settle near the order-0 expert and code close to entropy.
        let mut m = make();
        let mut rng = Pcg64::seed(4);
        let mut enc = Encoder::new();
        let mut n = 0usize;
        for _ in 0..120 {
            let ctx: Vec<i32> = (0..16 * 4).map(|_| rng.below(8) as i32).collect();
            let tgt: Vec<u16> =
                (0..16).map(|_| if rng.f64() < 0.9 { 0 } else { rng.below(8) as u16 }).collect();
            let probs = m.probs(&ctx).unwrap();
            for (r, &t) in tgt.iter().enumerate() {
                Cdf::from_probs(&probs[r * 8..(r + 1) * 8]).encode(&mut enc, t);
                n += 1;
            }
            m.update(&ctx, &tgt).unwrap();
        }
        let bits = enc.finish().len() as f64 * 8.0 / n as f64;
        // Entropy ≈ 0.9·log2(1/0.9) + ... ≈ 0.75 bits; allow transient.
        assert!(bits < 1.25, "bits/sym {bits}");
        // Order-0 expert should dominate the weights.
        let (wl, w0) = m.weights();
        assert!(w0 > wl, "w_lstm={wl} w_order0={w0}");
    }

    #[test]
    fn mixture_roundtrip_through_coder() {
        let mut rng = Pcg64::seed(5);
        let pairs: Vec<(Vec<i32>, Vec<u16>)> = (0..20)
            .map(|_| {
                (
                    (0..16 * 4).map(|_| rng.below(8) as i32).collect(),
                    (0..16).map(|_| rng.below(8) as u16).collect(),
                )
            })
            .collect();
        let mut enc_m = make();
        let mut enc = Encoder::new();
        for (ctx, tgt) in &pairs {
            let probs = enc_m.probs(ctx).unwrap();
            for (r, &t) in tgt.iter().enumerate() {
                Cdf::from_probs(&probs[r * 8..(r + 1) * 8]).encode(&mut enc, t);
            }
            enc_m.update(ctx, tgt).unwrap();
        }
        let buf = enc.finish();
        let mut dec_m = make();
        let mut dec = Decoder::new(&buf).unwrap();
        for (ctx, tgt) in &pairs {
            let probs = dec_m.probs(ctx).unwrap();
            let mut got = Vec::new();
            for r in 0..tgt.len() {
                got.push(Cdf::from_probs(&probs[r * 8..(r + 1) * 8]).decode(&mut dec));
            }
            assert_eq!(&got, tgt);
            dec_m.update(ctx, &got).unwrap();
        }
    }
}

//! The LSTM probability model driving the arithmetic coder (paper §III).
//!
//! For every weight to code, the quantized context sequence from the
//! *previous* checkpoint ([`crate::context`]) is fed through an embedding →
//! multi-layer LSTM → linear head → softmax, producing the symbol
//! distribution the range coder uses. After each batch the model takes one
//! Adam step on (contexts, observed symbols) — the online adaptation that
//! both encoder and decoder replay so no parameters are ever transmitted.
//!
//! Two interchangeable backends implement [`ProbModel`]:
//!
//! - [`native::NativeLstm`] — pure-Rust forward/BPTT/Adam. Fast on small
//!   configs, zero runtime dependencies, fully deterministic.
//! - [`pjrt::PjrtLstm`] — executes the AOT-compiled JAX programs (Layer 2,
//!   containing the Layer-1 Pallas cell) through [`crate::runtime`].
//!
//! The two backends use different parameter initializations and float
//! orderings, so streams are **not** interchangeable between them; the
//! container header records which backend (and config) wrote a stream, and
//! the decoder instantiates the same one. Within one backend, encode and
//! decode replay identical f32 operation sequences and therefore identical
//! probabilities — this is what makes the adaptive scheme lossless.

pub mod mix;
pub mod native;
pub mod pjrt;

use crate::runtime::RuntimeHandle;
use crate::{Error, Result};

/// Probability-model hyperparameters (mirror of python `LstmConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct LstmCfg {
    pub alphabet: usize,
    pub seq: usize,
    pub embed: usize,
    pub hidden: usize,
    pub layers: usize,
    pub batch: usize,
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    /// Parameter-init seed (both sides must agree; stored in containers).
    pub seed: u64,
}

impl Default for LstmCfg {
    /// Default experiment config: 4-bit alphabet, 3×3 context, h64
    /// (the paper's §IV optimizer hyperparameters).
    fn default() -> Self {
        Self {
            alphabet: 16,
            seq: 9,
            embed: 64,
            hidden: 64,
            layers: 2,
            batch: 256,
            lr: 1e-3,
            b1: 0.0,
            b2: 0.9999,
            eps: 1e-5,
            seed: 0,
        }
    }
}

impl LstmCfg {
    /// Paper §IV configuration: hidden 512 × 2 layers, embed 512, batch 256.
    pub fn paper() -> Self {
        Self { embed: 512, hidden: 512, ..Self::default() }
    }

    /// Tiny configuration used by unit tests.
    pub fn tiny() -> Self {
        Self { embed: 16, hidden: 16, batch: 32, ..Self::default() }
    }

    /// AOT program name prefix for this config
    /// (`lstm_a{A}_s{S}_h{H}_b{B}`; must exist in the manifest).
    pub fn program_prefix(&self) -> String {
        format!("lstm_a{}_s{}_h{}_b{}", self.alphabet, self.seq, self.hidden, self.batch)
    }

    /// Validate field ranges.
    pub fn validate(&self) -> Result<()> {
        if self.alphabet < 2 || self.alphabet > 4096 {
            return Err(Error::config("alphabet out of range"));
        }
        if self.seq == 0 || self.layers == 0 || self.hidden == 0 || self.batch == 0 {
            return Err(Error::config("zero-sized lstm dimension"));
        }
        Ok(())
    }
}

/// A batched, adaptively trained symbol-probability model.
///
/// Contract shared by encoder and decoder:
/// - `probs(contexts)` — `contexts` is `batch × seq` i32 symbols (row-major);
///   returns `batch × alphabet` probabilities. Must not mutate state.
/// - `update(contexts, targets)` — one optimizer step on the observed batch;
///   returns the training loss. Called after each coded batch.
///
/// Implementations must be deterministic: the same construction parameters
/// and call sequence must yield bit-identical probabilities.
pub trait ProbModel: Send {
    /// The model configuration.
    fn cfg(&self) -> &LstmCfg;
    /// Predict symbol distributions for a batch of context sequences.
    fn probs(&mut self, contexts: &[i32]) -> Result<Vec<f32>>;
    /// Adapt on the observed batch; returns the cross-entropy loss.
    fn update(&mut self, contexts: &[i32], targets: &[u16]) -> Result<f32>;
}

/// Which [`ProbModel`] implementation to use. Recorded (as `id()`) in the
/// container header so decode reconstructs the same one.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust LSTM.
    Native,
    /// AOT JAX/Pallas LSTM through the PJRT runtime thread.
    Pjrt(RuntimeHandle),
}

impl Backend {
    /// Stable identifier stored in containers.
    pub fn id(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Instantiate a fresh model in its initial state.
    pub fn make(&self, cfg: &LstmCfg) -> Result<Box<dyn ProbModel>> {
        cfg.validate()?;
        match self {
            Backend::Native => Ok(Box::new(native::NativeLstm::new(cfg.clone()))),
            Backend::Pjrt(h) => Ok(Box::new(pjrt::PjrtLstm::new(h.clone(), cfg.clone())?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_program_prefix() {
        assert_eq!(LstmCfg::default().program_prefix(), "lstm_a16_s9_h64_b256");
        assert_eq!(LstmCfg::tiny().program_prefix(), "lstm_a16_s9_h16_b32");
    }

    #[test]
    fn cfg_validation() {
        assert!(LstmCfg::default().validate().is_ok());
        assert!(LstmCfg { alphabet: 1, ..Default::default() }.validate().is_err());
        assert!(LstmCfg { seq: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn backend_ids() {
        assert_eq!(Backend::Native.id(), "native");
    }
}

//! Pure-Rust LSTM probability model: forward, backprop-through-time, Adam.
//!
//! Functionally equivalent to the JAX model in `python/compile/model.py`
//! (embedding → stacked LSTM, gate order i,f,g,o → linear head → softmax;
//! Adam with the paper's β1=0, β2=0.9999), but with its own deterministic
//! initialization — see the backend-compatibility note in [`super`].
//!
//! Gradient correctness is pinned by a finite-difference test over every
//! parameter tensor.

use super::{LstmCfg, ProbModel};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// One dense parameter tensor with its Adam state.
#[derive(Clone, Debug)]
struct Param {
    w: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    grad: Vec<f32>,
}

impl Param {
    fn new(w: Vec<f32>) -> Self {
        let n = w.len();
        Self { w, m: vec![0.0; n], v: vec![0.0; n], grad: vec![0.0; n] }
    }
}

/// Pure-Rust implementation of [`ProbModel`].
pub struct NativeLstm {
    cfg: LstmCfg,
    /// embed [A,E]
    embed: Param,
    /// per layer: wx [in,4H], wh [H,4H], b [4H]
    wx: Vec<Param>,
    wh: Vec<Param>,
    b: Vec<Param>,
    /// head [H,A], [A]
    head_w: Param,
    head_b: Param,
    /// Adam step count.
    step: u64,
    /// Forward caches (reused across calls to avoid allocation).
    cache: Cache,
}

/// Per-batch forward activations kept for BPTT.
#[derive(Default)]
struct Cache {
    /// gates[l][t]: [B,4H] post-activation (i,f,g,o)
    gates: Vec<Vec<Vec<f32>>>,
    /// h[l][t], c[l][t]: [B,H]
    h: Vec<Vec<Vec<f32>>>,
    c: Vec<Vec<Vec<f32>>>,
    /// logits / probs [B,A]
    probs: Vec<f32>,
}

impl NativeLstm {
    /// Fresh model with deterministic init from `cfg.seed`.
    pub fn new(cfg: LstmCfg) -> Self {
        let mut rng = Pcg64::new(cfg.seed, 0x15f3);
        let normal = |rng: &mut Pcg64, n: usize, fan_in: usize| -> Vec<f32> {
            let scale = 1.0 / (fan_in as f32).sqrt();
            (0..n).map(|_| rng.normal_f32() * scale).collect()
        };
        let a = cfg.alphabet;
        let e = cfg.embed;
        let hdim = cfg.hidden;
        let embed = Param::new(normal(&mut rng, a * e, e));
        let mut wx = Vec::new();
        let mut wh = Vec::new();
        let mut b = Vec::new();
        for l in 0..cfg.layers {
            let in_dim = if l == 0 { e } else { hdim };
            wx.push(Param::new(normal(&mut rng, in_dim * 4 * hdim, in_dim)));
            wh.push(Param::new(normal(&mut rng, hdim * 4 * hdim, hdim)));
            // Forget-gate bias = 1 (same trick as the JAX init).
            let mut bias = vec![0.0f32; 4 * hdim];
            bias[hdim..2 * hdim].fill(1.0);
            b.push(Param::new(bias));
        }
        let head_w = Param::new(normal(&mut rng, hdim * a, hdim));
        let head_b = Param::new(vec![0.0; a]);
        // Preallocate the BPTT caches for the maximum batch once; partial
        // batches use prefixes. This keeps update() allocation-free.
        let cache = Cache {
            gates: vec![vec![vec![0.0; cfg.batch * 4 * hdim]; cfg.seq]; cfg.layers],
            h: vec![vec![vec![0.0; cfg.batch * hdim]; cfg.seq]; cfg.layers],
            c: vec![vec![vec![0.0; cfg.batch * hdim]; cfg.seq]; cfg.layers],
            probs: vec![0.0; cfg.batch * a],
        };
        NativeLstm { cfg, embed, wx, wh, b, head_w, head_b, step: 0, cache }
    }

    /// Forward pass for `bsz` rows of `contexts` (bsz×seq); fills caches
    /// when `train` and returns probs [bsz, A].
    fn forward(&mut self, contexts: &[i32], bsz: usize, train: bool) -> Vec<f32> {
        let cfg = &self.cfg;
        let (a, e, hd, layers, seq) = (cfg.alphabet, cfg.embed, cfg.hidden, cfg.layers, cfg.seq);
        debug_assert_eq!(contexts.len(), bsz * seq);

        debug_assert!(bsz <= cfg.batch, "batch exceeds preallocated cache");

        // Rolling states.
        let mut hs = vec![vec![0.0f32; bsz * hd]; layers];
        let mut cs = vec![vec![0.0f32; bsz * hd]; layers];
        let mut x = vec![0.0f32; bsz * e.max(hd)];
        let mut gates = vec![0.0f32; bsz * 4 * hd];

        for t in 0..seq {
            // Embedding lookup for step t.
            for row in 0..bsz {
                let tok = contexts[row * seq + t].clamp(0, a as i32 - 1) as usize;
                x[row * e..row * e + e]
                    .copy_from_slice(&self.embed.w[tok * e..tok * e + e]);
            }
            let mut in_dim = e;
            for l in 0..layers {
                // gates = x @ wx + h @ wh + b
                for row in 0..bsz {
                    gates[row * 4 * hd..(row + 1) * 4 * hd]
                        .copy_from_slice(&self.b[l].w);
                }
                mm_acc(&x[..bsz * in_dim], &self.wx[l].w, &mut gates, bsz, in_dim, 4 * hd);
                mm_acc(&hs[l], &self.wh[l].w, &mut gates, bsz, hd, 4 * hd);
                // Nonlinearities + state update.
                let h = &mut hs[l];
                let c = &mut cs[l];
                for row in 0..bsz {
                    let g = &mut gates[row * 4 * hd..(row + 1) * 4 * hd];
                    for j in 0..hd {
                        let i_g = sigmoid(g[j]);
                        let f_g = sigmoid(g[hd + j]);
                        let g_g = fast_tanh(g[2 * hd + j]);
                        let o_g = sigmoid(g[3 * hd + j]);
                        let c_new = f_g * c[row * hd + j] + i_g * g_g;
                        c[row * hd + j] = c_new;
                        h[row * hd + j] = o_g * fast_tanh(c_new);
                        g[j] = i_g;
                        g[hd + j] = f_g;
                        g[2 * hd + j] = g_g;
                        g[3 * hd + j] = o_g;
                    }
                }
                if train {
                    self.cache.gates[l][t][..bsz * 4 * hd]
                        .copy_from_slice(&gates[..bsz * 4 * hd]);
                    self.cache.h[l][t][..bsz * hd].copy_from_slice(h);
                    self.cache.c[l][t][..bsz * hd].copy_from_slice(c);
                }
                // Next layer's input is this layer's hidden state.
                x[..bsz * hd].copy_from_slice(h);
                in_dim = hd;
            }
        }

        // Head + softmax.
        let top = &hs[layers - 1];
        let mut probs = vec![0.0f32; bsz * a];
        for row in 0..bsz {
            probs[row * a..(row + 1) * a].copy_from_slice(&self.head_b.w);
        }
        mm_acc(top, &self.head_w.w, &mut probs, bsz, hd, a);
        for row in 0..bsz {
            softmax_inplace(&mut probs[row * a..(row + 1) * a]);
        }
        if train {
            self.cache.probs[..bsz * a].copy_from_slice(&probs);
        }
        probs
    }

    /// Backward pass + Adam step. `contexts` bsz×seq, `targets` bsz.
    /// Returns mean cross-entropy loss.
    fn backward_and_step(&mut self, contexts: &[i32], targets: &[u16], bsz: usize) -> f32 {
        let cfg = self.cfg.clone();
        let (a, e, hd, layers, seq) = (cfg.alphabet, cfg.embed, cfg.hidden, cfg.layers, cfg.seq);

        // Loss + dlogits = (probs − onehot)/bsz.
        let probs = &self.cache.probs;
        let mut loss = 0.0f64;
        let mut dlogits = probs[..bsz * a].to_vec();
        for row in 0..bsz {
            let tgt = targets[row] as usize;
            let p = probs[row * a + tgt].max(1e-12);
            loss -= (p as f64).ln();
            dlogits[row * a + tgt] -= 1.0;
        }
        let inv = 1.0 / bsz as f32;
        for d in dlogits.iter_mut() {
            *d *= inv;
        }
        loss /= bsz as f64;

        // Zero all grads.
        for p in self.params_mut() {
            p.grad.iter_mut().for_each(|g| *g = 0.0);
        }

        // Head grads; dh into the top layer at t = seq−1.
        let top_h = &self.cache.h[layers - 1][seq - 1];
        // head_w.grad += top_hᵀ @ dlogits
        mm_tn_acc(top_h, &dlogits, &mut self.head_w.grad, bsz, hd, a);
        for row in 0..bsz {
            for j in 0..a {
                self.head_b.grad[j] += dlogits[row * a + j];
            }
        }

        // dh[l], dc[l] flowing backward in time.
        let mut dh = vec![vec![0.0f32; bsz * hd]; layers];
        let mut dc = vec![vec![0.0f32; bsz * hd]; layers];
        // dh_top(seq-1) += dlogits @ head_wᵀ
        mm_nt_acc(&dlogits, &self.head_w.w, &mut dh[layers - 1], bsz, a, hd);

        let mut dgates = vec![0.0f32; bsz * 4 * hd]; // pre-activation gate grads
        let mut dx = vec![0.0f32; bsz * e.max(hd)];
        let mut x_t = vec![0.0f32; bsz * e];
        let zero_c = vec![0.0f32; bsz * hd];

        for t in (0..seq).rev() {
            for l in (0..layers).rev() {
                let gates = &self.cache.gates[l][t];
                let c_t = &self.cache.c[l][t];
                // c_{t−1} is zero at t=0.
                let c_prev: &[f32] =
                    if t > 0 { &self.cache.c[l][t - 1] } else { &zero_c };
                // Gate-level gradients.
                for row in 0..bsz {
                    for j in 0..hd {
                        let idx = row * hd + j;
                        let gi = gates[row * 4 * hd + j];
                        let gf = gates[row * 4 * hd + hd + j];
                        let gg = gates[row * 4 * hd + 2 * hd + j];
                        let go = gates[row * 4 * hd + 3 * hd + j];
                        let tc = fast_tanh(c_t[idx]);
                        let dh_v = dh[l][idx];
                        let dct = dc[l][idx] + dh_v * go * (1.0 - tc * tc);
                        let d_o = dh_v * tc;
                        let d_i = dct * gg;
                        let d_g = dct * gi;
                        let d_f = dct * c_prev[idx];
                        // store pre-activation grads
                        dgates[row * 4 * hd + j] = d_i * gi * (1.0 - gi);
                        dgates[row * 4 * hd + hd + j] = d_f * gf * (1.0 - gf);
                        dgates[row * 4 * hd + 2 * hd + j] = d_g * (1.0 - gg * gg);
                        dgates[row * 4 * hd + 3 * hd + j] = d_o * go * (1.0 - go);
                        // dc flows to t−1 through the forget gate.
                        dc[l][idx] = dct * gf;
                    }
                }
                // Input to layer l at time t.
                let in_dim = if l == 0 { e } else { hd };
                // wh grad uses h_{t−1} (zero at t=0); dh_{t−1} += dgates @ whᵀ.
                if t > 0 {
                    let h_prev = &self.cache.h[l][t - 1];
                    mm_tn_acc(h_prev, &dgates, &mut self.wh[l].grad, bsz, hd, 4 * hd);
                    // reuse dx buffer for dh_prev
                    dx[..bsz * hd].iter_mut().for_each(|v| *v = 0.0);
                    mm_nt_acc(&dgates, &self.wh[l].w, &mut dx[..bsz * hd], bsz, 4 * hd, hd);
                    for (dst, src) in dh[l].iter_mut().zip(&dx[..bsz * hd]) {
                        // dh[l] at t−1 replaces the consumed dh at t.
                        *dst = *src;
                    }
                } else {
                    dh[l].iter_mut().for_each(|v| *v = 0.0);
                }

                // b grad.
                for row in 0..bsz {
                    for j in 0..4 * hd {
                        self.b[l].grad[j] += dgates[row * 4 * hd + j];
                    }
                }

                // x for this cell: embedding rows (l=0) or lower h (l>0).
                if l == 0 {
                    // wx grad against embeddings; d_embed scatter.
                    // Build x_t rows once.
                    x_t.iter_mut().for_each(|v| *v = 0.0);
                    for row in 0..bsz {
                        let tok = contexts[row * seq + t].clamp(0, a as i32 - 1) as usize;
                        x_t[row * e..row * e + e]
                            .copy_from_slice(&self.embed.w[tok * e..tok * e + e]);
                    }
                    mm_tn_acc(&x_t, &dgates, &mut self.wx[0].grad, bsz, e, 4 * hd);
                    // dx = dgates @ wxᵀ → scatter into embed.grad rows.
                    dx[..bsz * e].iter_mut().for_each(|v| *v = 0.0);
                    mm_nt_acc(&dgates, &self.wx[0].w, &mut dx[..bsz * e], bsz, 4 * hd, e);
                    for row in 0..bsz {
                        let tok = contexts[row * seq + t].clamp(0, a as i32 - 1) as usize;
                        for j in 0..e {
                            self.embed.grad[tok * e + j] += dx[row * e + j];
                        }
                    }
                } else {
                    let x_t = &self.cache.h[l - 1][t];
                    mm_tn_acc(x_t, &dgates, &mut self.wx[l].grad, bsz, in_dim, 4 * hd);
                    // dh of the lower layer at the same t accumulates.
                    dx[..bsz * hd].iter_mut().for_each(|v| *v = 0.0);
                    mm_nt_acc(&dgates, &self.wx[l].w, &mut dx[..bsz * hd], bsz, 4 * hd, hd);
                    for (dst, src) in dh[l - 1].iter_mut().zip(&dx[..bsz * hd]) {
                        *dst += *src;
                    }
                }
            }
        }

        // Adam.
        self.step += 1;
        let step = self.step;
        let (lr, b1, b2, eps) = (cfg.lr, cfg.b1, cfg.b2, cfg.eps);
        let bc1 = 1.0 - (b1 as f64).powi(step as i32);
        let bc2 = 1.0 - (b2 as f64).powi(step as i32);
        for p in self.params_mut() {
            for k in 0..p.w.len() {
                let g = p.grad[k];
                p.m[k] = b1 * p.m[k] + (1.0 - b1) * g;
                p.v[k] = b2 * p.v[k] + (1.0 - b2) * g * g;
                let mhat = p.m[k] / bc1 as f32;
                let vhat = p.v[k] / bc2 as f32;
                p.w[k] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        loss as f32
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut v: Vec<&mut Param> = vec![&mut self.embed];
        for p in self.wx.iter_mut() {
            v.push(p);
        }
        for p in self.wh.iter_mut() {
            v.push(p);
        }
        for p in self.b.iter_mut() {
            v.push(p);
        }
        v.push(&mut self.head_w);
        v.push(&mut self.head_b);
        v
    }

    /// Total parameter count (diagnostics).
    pub fn param_count(&self) -> usize {
        let mut n = self.embed.w.len() + self.head_w.w.len() + self.head_b.w.len();
        for l in 0..self.cfg.layers {
            n += self.wx[l].w.len() + self.wh[l].w.len() + self.b[l].w.len();
        }
        n
    }

    #[cfg(test)]
    fn loss_only(&mut self, contexts: &[i32], targets: &[u16], bsz: usize) -> f32 {
        let probs = self.forward(contexts, bsz, false);
        let a = self.cfg.alphabet;
        let mut loss = 0.0f64;
        for row in 0..bsz {
            let p = probs[row * a + targets[row] as usize].max(1e-12);
            loss -= (p as f64).ln();
        }
        (loss / bsz as f64) as f32
    }
}

impl ProbModel for NativeLstm {
    fn cfg(&self) -> &LstmCfg {
        &self.cfg
    }

    fn probs(&mut self, contexts: &[i32]) -> Result<Vec<f32>> {
        let bsz = batch_of(contexts.len(), self.cfg.seq)?;
        Ok(self.forward(contexts, bsz, false))
    }

    fn update(&mut self, contexts: &[i32], targets: &[u16]) -> Result<f32> {
        let bsz = batch_of(contexts.len(), self.cfg.seq)?;
        if targets.len() != bsz {
            return Err(Error::shape("targets length != batch"));
        }
        self.forward(contexts, bsz, true);
        Ok(self.backward_and_step(contexts, targets, bsz))
    }
}

fn batch_of(ctx_len: usize, seq: usize) -> Result<usize> {
    if ctx_len % seq != 0 || ctx_len == 0 {
        return Err(Error::shape(format!("context buffer {ctx_len} not a multiple of seq {seq}")));
    }
    Ok(ctx_len / seq)
}

/// Fast tanh: clamped Padé-type rational approximation (|err| < 4e-3,
/// exact sign/saturation). The model only consumes these values through
/// its own probabilities, and encoder/decoder share the implementation, so
/// the approximation is fully self-consistent. ~6× cheaper than libm tanh
/// and auto-vectorizable.
#[inline]
fn fast_tanh(x: f32) -> f32 {
    let x = x.clamp(-4.97, 4.97);
    let x2 = x * x;
    let p = x * (135_135.0 + x2 * (17_325.0 + x2 * (378.0 + x2)));
    let q = 135_135.0 + x2 * (62_370.0 + x2 * (3_150.0 + x2 * 28.0));
    p / q
}

/// Fast sigmoid via `0.5·(1 + tanh(x/2))`.
#[inline]
fn sigmoid(x: f32) -> f32 {
    0.5 * (1.0 + fast_tanh(0.5 * x))
}

fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// out[M,N] += a[M,K] @ b[K,N] (ikj loop order, row-major; branch-free
/// inner loops so LLVM vectorizes the `axpy` over N).
fn mm_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    for i in 0..m {
        let out_row = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let a_ik = a[i * k + kk];
            let b_row = &b[kk * n..kk * n + n];
            for (o, &bv) in out_row.iter_mut().zip(b_row) {
                *o += a_ik * bv;
            }
        }
    }
}

/// out[K,N] += aᵀ[K,M] @ b[M,N] where a is [M,K] (grad of weights).
fn mm_tn_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert!(a.len() >= m * k && b.len() >= m * n && out.len() >= k * n);
    for row in 0..m {
        let b_row = &b[row * n..row * n + n];
        for kk in 0..k {
            let a_v = a[row * k + kk];
            let out_row = &mut out[kk * n..kk * n + n];
            for (o, &bv) in out_row.iter_mut().zip(b_row) {
                *o += a_v * bv;
            }
        }
    }
}

/// out[M,K] += a[M,N] @ bᵀ[N,K] where b is [K,N] (grad of inputs).
/// Row-dot form; the 4-way unrolled accumulator lets LLVM keep four
/// independent vector chains (f32 adds are not reassociable by default).
fn mm_nt_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert!(a.len() >= m * n && b.len() >= k * n && out.len() >= m * k);
    for i in 0..m {
        let a_row = &a[i * n..i * n + n];
        let out_row = &mut out[i * k..i * k + k];
        for kk in 0..k {
            let b_row = &b[kk * n..kk * n + n];
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            let mut s3 = 0.0f32;
            let chunks = n / 4;
            for c in 0..chunks {
                let j = c * 4;
                s0 += a_row[j] * b_row[j];
                s1 += a_row[j + 1] * b_row[j + 1];
                s2 += a_row[j + 2] * b_row[j + 2];
                s3 += a_row[j + 3] * b_row[j + 3];
            }
            let mut s = s0 + s1 + s2 + s3;
            for j in chunks * 4..n {
                s += a_row[j] * b_row[j];
            }
            out_row[kk] += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tiny_cfg() -> LstmCfg {
        LstmCfg { alphabet: 8, seq: 4, embed: 6, hidden: 5, layers: 2, batch: 3, ..Default::default() }
    }

    fn random_batch(cfg: &LstmCfg, seed: u64) -> (Vec<i32>, Vec<u16>) {
        let mut rng = Pcg64::seed(seed);
        let ctx: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.alphabet as u64) as i32).collect();
        let tgt: Vec<u16> =
            (0..cfg.batch).map(|_| rng.below(cfg.alphabet as u64) as u16).collect();
        (ctx, tgt)
    }

    #[test]
    fn probs_are_distributions() {
        let cfg = tiny_cfg();
        let mut model = NativeLstm::new(cfg.clone());
        let (ctx, _) = random_batch(&cfg, 1);
        let probs = model.probs(&ctx).unwrap();
        assert_eq!(probs.len(), cfg.batch * cfg.alphabet);
        for row in probs.chunks(cfg.alphabet) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "sum={s}");
            assert!(row.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let cfg = tiny_cfg();
        let (ctx, tgt) = random_batch(&cfg, 2);
        let mut a = NativeLstm::new(cfg.clone());
        let mut b = NativeLstm::new(cfg.clone());
        assert_eq!(a.probs(&ctx).unwrap(), b.probs(&ctx).unwrap());
        let la = a.update(&ctx, &tgt).unwrap();
        let lb = b.update(&ctx, &tgt).unwrap();
        assert_eq!(la, lb);
        assert_eq!(a.probs(&ctx).unwrap(), b.probs(&ctx).unwrap());
    }

    #[test]
    fn seed_changes_model() {
        let cfg = tiny_cfg();
        let (ctx, _) = random_batch(&cfg, 3);
        let mut a = NativeLstm::new(cfg.clone());
        let mut b = NativeLstm::new(LstmCfg { seed: 99, ..cfg });
        assert_ne!(a.probs(&ctx).unwrap(), b.probs(&ctx).unwrap());
    }

    #[test]
    fn gradcheck_finite_difference() {
        // Central finite differences on a handful of coordinates of every
        // parameter tensor. f64-free (model is f32) so tolerances are loose
        // but directionally tight.
        let cfg = tiny_cfg();
        let (ctx, tgt) = random_batch(&cfg, 4);
        let bsz = cfg.batch;

        // Analytic grads (no Adam step side effect matters for comparison;
        // grads are recomputed fresh in backward).
        let mut model = NativeLstm::new(cfg.clone());
        model.forward(&ctx, bsz, true);
        // Run backward WITHOUT letting Adam overwrite weights first: copy.
        let mut probe = NativeLstm::new(cfg.clone());
        probe.forward(&ctx, bsz, true);
        probe.backward_and_step(&ctx, &tgt, bsz);
        // probe.grad now holds analytic grads (weights already stepped, but
        // grads are what we compare).

        let eps = 3e-3f32;
        let n_params = probe.params_mut().len();
        for pi in 0..n_params {
            let plen = {
                let mut fresh = NativeLstm::new(cfg.clone());
                fresh.params_mut()[pi].w.len()
            };
            // Probe a few spread-out coordinates.
            for &frac in &[0usize, plen / 3, plen / 2, plen - 1] {
                let idx = frac.min(plen - 1);
                let mut plus = NativeLstm::new(cfg.clone());
                plus.params_mut()[pi].w[idx] += eps;
                let lp = plus.loss_only(&ctx, &tgt, bsz);
                let mut minus = NativeLstm::new(cfg.clone());
                minus.params_mut()[pi].w[idx] -= eps;
                let lm = minus.loss_only(&ctx, &tgt, bsz);
                let fd = (lp - lm) / (2.0 * eps);
                let an = probe.params_mut()[pi].grad[idx];
                let tol = 2e-2f32.max(0.15 * an.abs());
                assert!(
                    (fd - an).abs() < tol,
                    "param {pi} idx {idx}: fd={fd:.5} analytic={an:.5}"
                );
            }
        }
    }

    #[test]
    fn learns_deterministic_mapping() {
        // Train on a fixed (context → symbol) pair; its probability must
        // grow — this is the codec's adaptation contract.
        let cfg = tiny_cfg();
        let mut model = NativeLstm::new(cfg.clone());
        let ctx: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % 3) as i32).collect();
        let tgt = vec![5u16; cfg.batch];
        let p_before = model.probs(&ctx).unwrap()[5];
        let mut losses = Vec::new();
        for _ in 0..300 {
            losses.push(model.update(&ctx, &tgt).unwrap());
        }
        let p_after = model.probs(&ctx).unwrap()[5];
        assert!(losses.last().unwrap() < &(losses[0] * 0.5), "losses={losses:?}");
        assert!(p_after > p_before);
        assert!(p_after > 0.5, "p_after={p_after}");
    }

    #[test]
    fn variable_batch_sizes() {
        // The codec's final partial batch uses fewer rows.
        let cfg = tiny_cfg();
        let mut model = NativeLstm::new(cfg.clone());
        let ctx1: Vec<i32> = vec![1; cfg.seq]; // single row
        let p = model.probs(&ctx1).unwrap();
        assert_eq!(p.len(), cfg.alphabet);
        let bad: Vec<i32> = vec![1; cfg.seq + 1];
        assert!(model.probs(&bad).is_err());
    }

    #[test]
    fn mm_helpers_match_naive() {
        let mut rng = Pcg64::seed(5);
        let (m, k, n) = (3usize, 4usize, 5usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut out = vec![0.0f32; m * n];
        mm_acc(&a, &b, &mut out, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|x| a[i * k + x] * b[x * n + j]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-5);
            }
        }
        // aᵀ @ c where c is [M,N]: out2[K,N]
        let c: Vec<f32> = (0..m * n).map(|_| rng.normal_f32()).collect();
        let mut out2 = vec![0.0f32; k * n];
        mm_tn_acc(&a, &c, &mut out2, m, k, n);
        for kk in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|r| a[r * k + kk] * c[r * n + j]).sum();
                assert!((out2[kk * n + j] - want).abs() < 1e-5);
            }
        }
        // c @ bᵀ... use dims: a2 [M,N] @ bᵀ where b [K,N] → [M,K]
        let mut out3 = vec![0.0f32; m * k];
        mm_nt_acc(&c, &b, &mut out3, m, n, k);
        for i in 0..m {
            for kk in 0..k {
                let want: f32 = (0..n).map(|j| c[i * n + j] * b[kk * n + j]).sum();
                assert!((out3[i * k + kk] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn param_count_matches_formula() {
        let cfg = tiny_cfg();
        let model = NativeLstm::new(cfg.clone());
        let (a, e, h) = (cfg.alphabet, cfg.embed, cfg.hidden);
        let expect = a * e
            + (e * 4 * h + h * 4 * h + 4 * h)      // layer 0
            + (h * 4 * h + h * 4 * h + 4 * h)      // layer 1
            + h * a + a;
        assert_eq!(model.param_count(), expect);
    }
}

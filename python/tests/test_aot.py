"""AOT path tests: HLO-text lowering and manifest consistency.

These validate the compile-path contract the Rust runtime depends on:
- every program lowers to parseable HLO text with `return_tuple=True`;
- the manifest's parameter layout matches the model's spec exactly;
- init/probs/train signatures agree with what `rust/src/lstm/pjrt.rs`
  and `rust/src/trainer` assume positionally.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

import compile.model as M
from compile.aot import Emitter, emit_lstm, to_hlo_text, lstm_configs, lm_configs


TINY = M.LstmConfig(alphabet=16, seq=9, embed=16, hidden=16, batch=32)


def test_to_hlo_text_emits_entry_computation():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4,4]" in text
    # return_tuple=True → tuple-shaped root.
    assert "ROOT tuple" in text


def test_emitter_writes_files_and_manifest(tmp_path):
    e = Emitter(str(tmp_path))
    emit_lstm(e, TINY)
    e.finish()
    files = os.listdir(tmp_path)
    assert f"{TINY.name}_probs.hlo.txt" in files
    assert f"{TINY.name}_train.hlo.txt" in files
    assert f"{TINY.name}_init.hlo.txt" in files
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["version"] == 1
    probs = manifest["programs"][f"{TINY.name}_probs"]
    assert probs["kind"] == "lstm_probs"
    assert probs["config"]["alphabet"] == 16
    # Param layout mirrors the model spec exactly.
    spec = M.lstm_param_spec(TINY)
    assert [(p["name"], tuple(p["shape"])) for p in probs["params"]] == [
        (n, tuple(s)) for n, s in spec
    ]


def test_default_config_matrix_covers_required_programs():
    """The Rust side hard-codes a few program prefixes; keep them emitted."""
    lstm_names = {c.name for c in lstm_configs(full=False)}
    assert "lstm_a16_s9_h64_b256" in lstm_names   # default codec config
    assert "lstm_a16_s9_h16_b32" in lstm_names    # test config
    assert "lstm_a4_s9_h64_b256" in lstm_names    # 2-bit ablation
    assert "lstm_a16_s1_h64_b256" in lstm_names   # window=1 ablation
    assert "lstm_a16_s25_h64_b256" in lstm_names  # window=5 ablation
    lm_names = {c.name for c in lm_configs(full=False)}
    assert {"lm_micro", "lm_tiny", "lm_small"} <= lm_names


def test_paper_scale_configs_behind_full_flag():
    full_lstm = {c.name for c in lstm_configs(full=True)}
    assert "lstm_a16_s9_h512_b256" in full_lstm  # paper §IV hyperparameters
    full_lm = {c.name for c in lm_configs(full=True)}
    assert "lm_base" in full_lm


def test_lstm_train_signature_matches_pjrt_expectations():
    """(params, m, v, step, tokens, targets) → (params', m', v', loss)."""
    n = len(M.lstm_param_spec(TINY))
    flat = M.lstm_init_fn(TINY)(jnp.int32(0))
    zeros = [jnp.zeros_like(p) for p in flat]
    tokens = jnp.zeros((TINY.batch, TINY.seq), jnp.int32)
    targets = jnp.zeros((TINY.batch,), jnp.int32)
    out = M.lstm_train_fn(TINY)(*flat, *zeros, *zeros, jnp.float32(1.0), tokens, targets)
    assert len(out) == 3 * n + 1
    for i in range(n):
        assert out[i].shape == flat[i].shape
    assert out[-1].shape == ()


def test_manifest_rejects_shape_drift(tmp_path):
    """If the model spec and emitted example args ever diverge, lowering
    must fail loudly rather than emit an inconsistent artifact."""
    e = Emitter(str(tmp_path))
    bad_shapes = [jax.ShapeDtypeStruct((3, 3), jnp.float32)]  # wrong arity
    with pytest.raises(Exception):
        e.emit(
            "bad", M.lstm_probs_fn(TINY), bad_shapes, ["x"], "lstm_probs",
            {}, M.lstm_param_spec(TINY),
        )

"""L1 correctness: Pallas fused LSTM cell vs the pure-jnp oracle.

`hypothesis` is unavailable in this environment (DESIGN.md §6), so the
shape/dtype sweep is a dense pytest.mark.parametrize grid plus seeded
random draws — the same coverage style, deterministic by construction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.ref import lstm_cell_ref, softmax_ref


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _case(batch, embed, hidden, seed, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = _rand(ks[0], batch, embed, scale=scale)
    h = _rand(ks[1], batch, hidden, scale=scale)
    c = _rand(ks[2], batch, hidden, scale=scale)
    wx = _rand(ks[3], embed, 4 * hidden, scale=scale / np.sqrt(embed))
    wh = _rand(ks[4], hidden, 4 * hidden, scale=scale / np.sqrt(hidden))
    b = _rand(ks[5], 4 * hidden, scale=0.1)
    return x, h, c, wx, wh, b


# Shape sweep: batch sizes that exercise every tile path (1, non-pow2
# composite, exactly one tile, many tiles), embed != hidden, tiny dims.
SHAPES = [
    (1, 4, 4),
    (2, 8, 4),
    (3, 5, 7),      # odd batch → tile 1
    (6, 16, 8),     # tile 2
    (32, 16, 16),
    (64, 32, 16),
    (128, 16, 32),  # one full 128 tile
    (256, 32, 32),  # two tiles
    (96, 24, 40),   # tile 32, ragged dims
]


@pytest.mark.parametrize("batch,embed,hidden", SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_cell_matches_ref(batch, embed, hidden, seed):
    args = _case(batch, embed, hidden, seed)
    h_k, c_k = lstm_cell(*args)
    h_r, c_r = lstm_cell_ref(*args)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("scale", [1e-3, 1.0, 10.0])
def test_cell_extreme_scales(scale):
    """Saturation regions of sigmoid/tanh must still agree."""
    args = _case(16, 8, 8, seed=3, scale=scale)
    h_k, c_k = lstm_cell(*args)
    h_r, c_r = lstm_cell_ref(*args)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)


def test_cell_zero_state():
    """All-zero h/c (the codec's initial state every batch)."""
    x, _, _, wx, wh, b = _case(32, 16, 16, seed=4)
    z = jnp.zeros((32, 16), jnp.float32)
    h_k, c_k = lstm_cell(x, z, z, wx, wh, b)
    h_r, c_r = lstm_cell_ref(x, z, z, wx, wh, b)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)


def test_cell_bounded_outputs():
    """|h| ≤ 1 by construction (o·tanh); c bounded by |c_in| + 1."""
    args = _case(64, 32, 32, seed=5, scale=5.0)
    h_k, c_k = lstm_cell(*args)
    assert np.all(np.abs(np.asarray(h_k)) <= 1.0 + 1e-6)
    assert np.all(np.abs(np.asarray(c_k)) <= np.abs(np.asarray(args[2])) + 1.0 + 1e-6)


def test_cell_jit_and_grad_path():
    """The custom-vjp wrapper in model.py must differentiate cleanly."""
    from compile.model import _cell

    args = _case(8, 8, 8, seed=6)

    def loss(wx):
        h, c = _cell(args[0], args[1], args[2], wx, args[4], args[5])
        return (h**2).sum() + (c**2).sum()

    g = jax.grad(loss)(args[3])

    def loss_ref(wx):
        h, c = lstm_cell_ref(args[0], args[1], args[2], wx, args[4], args[5])
        return (h**2).sum() + (c**2).sum()

    g_ref = jax.grad(loss_ref)(args[3])
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-6)


def test_softmax_ref_sanity():
    logits = jnp.array([[0.0, 1.0, 2.0], [5.0, 5.0, 5.0]], jnp.float32)
    p = softmax_ref(logits)
    np.testing.assert_allclose(p.sum(-1), np.ones(2), rtol=1e-6)
    assert p[0, 2] > p[0, 1] > p[0, 0]
    np.testing.assert_allclose(p[1], np.full(3, 1 / 3), rtol=1e-6)

"""L2 correctness: probability model, workloads, Adam semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.model as M
from compile.kernels.ref import lstm_stack_ref


TINY = M.LstmConfig(alphabet=16, seq=9, embed=16, hidden=16, batch=8)


def _lstm_params(cfg, seed=0):
    return M.lstm_init_fn(cfg)(jnp.int32(seed))


def test_lstm_param_spec_shapes():
    spec = M.lstm_param_spec(TINY)
    names = [n for n, _ in spec]
    assert names[0] == "embed"
    assert "l0.wx" in names and "l1.wh" in names
    assert names[-2:] == ["head.w", "head.b"]
    flat = _lstm_params(TINY)
    assert len(flat) == len(spec)
    for (name, shape), arr in zip(spec, flat):
        assert arr.shape == shape, name


def test_lstm_init_deterministic_and_seed_sensitive():
    a = _lstm_params(TINY, 1)
    b = _lstm_params(TINY, 1)
    c = _lstm_params(TINY, 2)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_lstm_forget_gate_bias():
    spec = M.lstm_param_spec(TINY)
    flat = _lstm_params(TINY)
    for (name, _), arr in zip(spec, flat):
        if name in ("l0.b", "l1.b"):
            h = arr.shape[0] // 4
            np.testing.assert_array_equal(arr[h : 2 * h], np.ones(h))
            np.testing.assert_array_equal(arr[:h], np.zeros(h))


def test_probs_valid_distribution():
    flat = _lstm_params(TINY)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (TINY.batch, TINY.seq), 0, TINY.alphabet)
    (probs,) = M.lstm_probs_fn(TINY)(*flat, tokens)
    assert probs.shape == (TINY.batch, TINY.alphabet)
    np.testing.assert_allclose(probs.sum(-1), np.ones(TINY.batch), rtol=1e-5)
    assert np.all(np.asarray(probs) >= 0)


def test_probs_match_ref_trunk():
    """The pallas-backed trunk must agree with the jnp reference stack."""
    flat = _lstm_params(TINY)
    spec = M.lstm_param_spec(TINY)
    params = {n: a for (n, _), a in zip(spec, flat)}
    tokens = jax.random.randint(jax.random.PRNGKey(4), (TINY.batch, TINY.seq), 0, TINY.alphabet)
    h_ref = lstm_stack_ref(tokens, params, TINY.layers, TINY.hidden)
    logits_ref = h_ref @ params["head.w"] + params["head.b"]
    probs_ref = jax.nn.softmax(logits_ref, -1)
    (probs,) = M.lstm_probs_fn(TINY)(*flat, tokens)
    np.testing.assert_allclose(probs, probs_ref, rtol=1e-4, atol=1e-6)


def test_probs_depend_on_context():
    flat = _lstm_params(TINY)
    t0 = jnp.zeros((TINY.batch, TINY.seq), jnp.int32)
    t1 = jnp.full((TINY.batch, TINY.seq), TINY.alphabet - 1, jnp.int32)
    (p0,) = M.lstm_probs_fn(TINY)(*flat, t0)
    (p1,) = M.lstm_probs_fn(TINY)(*flat, t1)
    assert not np.allclose(p0, p1)


def test_lstm_train_step_learns_constant_mapping():
    """Repeatedly training on one (context → symbol) pair must drive its
    probability up — the online-adaptation mechanism of the codec."""
    cfg = TINY
    n = len(M.lstm_param_spec(cfg))
    flat = list(_lstm_params(cfg))
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    tokens = jnp.tile(jnp.arange(cfg.seq, dtype=jnp.int32)[None], (cfg.batch, 1)) % cfg.alphabet
    targets = jnp.full((cfg.batch,), 5, jnp.int32)
    train = jax.jit(M.lstm_train_fn(cfg))
    probs_fn = jax.jit(M.lstm_probs_fn(cfg))

    (p_before,) = probs_fn(*flat, tokens)
    losses = []
    for step in range(1, 81):
        out = train(*flat, *m, *v, jnp.float32(step), tokens, targets)
        flat, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        losses.append(float(out[-1]))
    (p_after,) = probs_fn(*flat, tokens)
    assert losses[-1] < losses[0] * 0.5, losses
    assert float(p_after[0, 5]) > float(p_before[0, 5])
    assert float(p_after[0, 5]) > 0.5


def test_adam_step_matches_reference():
    """Flat Adam vs a hand-computed single step."""
    p = [jnp.array([1.0, 2.0], jnp.float32)]
    g = [jnp.array([0.1, -0.2], jnp.float32)]
    m = [jnp.zeros(2, jnp.float32)]
    v = [jnp.zeros(2, jnp.float32)]
    lr, b1, b2, eps = 0.01, 0.9, 0.999, 1e-8
    new_p, new_m, new_v = M.adam_step(p, g, m, v, jnp.float32(1.0), lr, b1, b2, eps)
    m1 = (1 - b1) * np.asarray(g[0])
    v1 = (1 - b2) * np.asarray(g[0]) ** 2
    mhat = m1 / (1 - b1)
    vhat = v1 / (1 - b2)
    expect = np.asarray(p[0]) - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(new_p[0], expect, rtol=1e-6)
    np.testing.assert_allclose(new_m[0], m1, rtol=1e-6)
    np.testing.assert_allclose(new_v[0], v1, rtol=1e-6)


def test_rmsprop_mode_beta1_zero():
    """β1=0 (paper §IV) ⇒ m equals the raw gradient."""
    p = [jnp.array([1.0], jnp.float32)]
    g = [jnp.array([0.5], jnp.float32)]
    m = [jnp.array([9.9], jnp.float32)]  # stale value must vanish
    v = [jnp.zeros(1, jnp.float32)]
    _, new_m, _ = M.adam_step(p, g, m, v, jnp.float32(3.0), 1e-3, 0.0, 0.9999, 1e-5)
    np.testing.assert_allclose(new_m[0], g[0], rtol=1e-6)


# ----------------------------- LM workload --------------------------------

LM = M.LmConfig(tag="test", vocab=64, dim=32, layers=2, heads=2, seq=16, batch=4)


def test_lm_param_count_and_shapes():
    spec = M.lm_param_spec(LM)
    flat = M.lm_init_fn(LM)(jnp.int32(0))
    assert len(flat) == len(spec)
    for (name, shape), arr in zip(spec, flat):
        assert arr.shape == shape, name
    total = sum(int(np.prod(s)) for _, s in spec)
    assert total > 10_000


def test_lm_loss_near_uniform_at_init():
    flat = M.lm_init_fn(LM)(jnp.int32(0))
    spec = M.lm_param_spec(LM)
    params = {n: a for (n, _), a in zip(spec, flat)}
    tokens = jax.random.randint(jax.random.PRNGKey(1), (LM.batch, LM.seq + 1), 0, LM.vocab)
    loss = M.lm_loss(params, tokens, LM)
    assert abs(float(loss) - np.log(LM.vocab)) < 0.5


def test_lm_train_reduces_loss_on_fixed_batch():
    n = len(M.lm_param_spec(LM))
    flat = list(M.lm_init_fn(LM)(jnp.int32(0)))
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    tokens = jax.random.randint(jax.random.PRNGKey(2), (LM.batch, LM.seq + 1), 0, LM.vocab)
    train = jax.jit(M.lm_train_fn(LM))
    first = None
    for step in range(1, 41):
        out = train(*flat, *m, *v, jnp.float32(step), tokens)
        flat, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        loss = float(out[-1])
        if first is None:
            first = loss
    # lr = 3e-4: expect a steady ~0.6-nat drop over 40 steps on a fixed batch.
    assert loss < first - 0.4, (first, loss)


def test_lm_eval_matches_loss():
    flat = M.lm_init_fn(LM)(jnp.int32(0))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (LM.batch, LM.seq + 1), 0, LM.vocab)
    (ev,) = M.lm_eval_fn(LM)(*flat, tokens)
    spec = M.lm_param_spec(LM)
    params = {n: a for (n, _), a in zip(spec, flat)}
    np.testing.assert_allclose(ev, M.lm_loss(params, tokens, LM), rtol=1e-6)


# ----------------------------- ViT workload -------------------------------

VIT = M.VitConfig(tag="test", patches=8, patch_dim=12, dim=32, layers=1, heads=2,
                  classes=8, batch=8)


def test_vit_shapes_and_loss():
    spec = M.vit_param_spec(VIT)
    flat = M.vit_init_fn(VIT)(jnp.int32(0))
    for (name, shape), arr in zip(spec, flat):
        assert arr.shape == shape, name
    images = jax.random.normal(jax.random.PRNGKey(1), (VIT.batch, VIT.patches, VIT.patch_dim))
    labels = jax.random.randint(jax.random.PRNGKey(2), (VIT.batch,), 0, VIT.classes)
    params = {n: a for (n, _), a in zip(spec, flat)}
    loss = M.vit_loss(params, images, labels, VIT)
    assert abs(float(loss) - np.log(VIT.classes)) < 0.5


def test_vit_train_reduces_loss():
    n = len(M.vit_param_spec(VIT))
    flat = list(M.vit_init_fn(VIT)(jnp.int32(0)))
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    images = jax.random.normal(jax.random.PRNGKey(3), (VIT.batch, VIT.patches, VIT.patch_dim))
    labels = jax.random.randint(jax.random.PRNGKey(4), (VIT.batch,), 0, VIT.classes)
    train = jax.jit(M.vit_train_fn(VIT))
    first = last = None
    for step in range(1, 41):
        out = train(*flat, *m, *v, jnp.float32(step), images, labels)
        flat, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        last = float(out[-1])
        if first is None:
            first = last
    # Memorizing 8 random images at lr 3e-4 over 40 steps.
    assert last < first - 0.3, (first, last)

"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain `jax.numpy` only — no Pallas, no custom lowering. The pytest
suite asserts `assert_allclose(kernel(...), ref(...))` over a sweep of
shapes and dtypes; this is the core L1 correctness signal.
"""

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Reference LSTM cell (same gate layout as kernels.lstm_cell: i,f,g,o)."""
    gates = x @ wx + h @ wh + b
    hidden = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, :hidden])
    f = jax.nn.sigmoid(gates[:, hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def lstm_stack_ref(tokens, params, num_layers, hidden):
    """Reference multi-layer LSTM over a token sequence.

    Mirrors model.lstm_probs but uses lstm_cell_ref throughout.
    tokens: [B, S] int32; returns final top-layer hidden state [B, H].
    """
    emb = params["embed"][tokens]  # [B, S, E]
    batch, seq, _ = emb.shape
    hs = [jnp.zeros((batch, hidden), emb.dtype) for _ in range(num_layers)]
    cs = [jnp.zeros((batch, hidden), emb.dtype) for _ in range(num_layers)]
    for t in range(seq):
        inp = emb[:, t, :]
        for layer in range(num_layers):
            wx = params[f"l{layer}.wx"]
            wh = params[f"l{layer}.wh"]
            b = params[f"l{layer}.b"]
            hs[layer], cs[layer] = lstm_cell_ref(inp, hs[layer], cs[layer], wx, wh, b)
            inp = hs[layer]
    return hs[-1]


def softmax_ref(logits):
    """Numerically stable softmax reference."""
    z = logits - logits.max(axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / e.sum(axis=-1, keepdims=True)

"""Layer-1 Pallas kernel: fused LSTM cell.

The compression hot-spot of the paper is the LSTM probability model
(2 layers, hidden 512, sequence length 9, batch 256 — §IV). One LSTM cell
step is

    gates = x @ Wx + h @ Wh + b          # [B, 4H]
    i, f, g, o = split(gates, 4, axis=1)
    c' = sigmoid(f) * c + sigmoid(i) * tanh(g)
    h' = sigmoid(o) * tanh(c')

This kernel fuses both matmuls, the bias add, all four gate nonlinearities
and the state update into one Pallas program, tiled over the batch
dimension.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA reference
implementation would assign one threadblock per batch tile with the weights
staged through shared memory. On TPU the same schedule is expressed with a
1-D grid over batch tiles and BlockSpecs that keep the full `[E, 4H]` /
`[H, 4H]` weight panels resident in VMEM while streaming `[Bt, ·]`
activations — the two matmuls then drive the MXU directly. With the paper
configuration (E = H = 512, f32) the VMEM footprint is

    Wx 512×2048×4B = 4 MiB   Wh 512×2048×4B = 4 MiB
    x/h/c/h'/c' tiles (Bt=128): 5 × 128×512×4B ≈ 1.3 MiB   total ≈ 9.4 MiB

which fits a 16 MiB VMEM core with double-buffering headroom on the
activation tiles only; bf16 weights would halve it.

`interpret=True` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Interpret mode lowers
to plain HLO so the same program runs everywhere (and is what `aot.py`
ships to the Rust runtime).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    """One fused LSTM cell step for a [Bt, ·] batch tile."""
    # Both matmuls in f32; prefer MXU-friendly accumulation.
    gates = (
        jnp.dot(x_ref[...], wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h_ref[...], wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    hidden = c_ref.shape[-1]
    i = jax.nn.sigmoid(gates[:, :hidden])
    f = jax.nn.sigmoid(gates[:, hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden :])
    c_new = f * c_ref[...] + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


def _pick_batch_tile(batch: int) -> int:
    """Largest power-of-two tile ≤ 128 that divides the batch."""
    tile = 1
    for cand in (2, 4, 8, 16, 32, 64, 128):
        if batch % cand == 0:
            tile = cand
    return tile


@functools.partial(jax.jit, static_argnames=())
def lstm_cell(x, h, c, wx, wh, b):
    """Fused LSTM cell step.

    Args:
      x:  [B, E] input activations.
      h:  [B, H] previous hidden state.
      c:  [B, H] previous cell state.
      wx: [E, 4H] input projection.
      wh: [H, 4H] recurrent projection.
      b:  [4H] gate bias (i, f, g, o blocks).

    Returns:
      (h', c'): updated hidden and cell states, both [B, H].
    """
    batch, _embed = x.shape
    hidden = h.shape[-1]
    tile = _pick_batch_tile(batch)
    grid = (batch // tile,)
    b2 = b.reshape(1, -1)  # TPU-friendly 2-D scalarless layout

    h_new, c_new = pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, x.shape[1]), lambda i: (i, 0)),     # x tile
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),          # h tile
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),          # c tile
            pl.BlockSpec((wx.shape[0], wx.shape[1]), lambda i: (0, 0)),  # Wx resident
            pl.BlockSpec((wh.shape[0], wh.shape[1]), lambda i: (0, 0)),  # Wh resident
            pl.BlockSpec((1, b2.shape[1]), lambda i: (0, 0)),        # bias resident
        ],
        out_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, hidden), x.dtype),
            jax.ShapeDtypeStruct((batch, hidden), x.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, h, c, wx, wh, b2)
    return h_new, c_new

"""AOT compile path: lower every Layer-2 program to HLO *text* + manifest.

Run once by `make artifacts` (never at request time):

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside the .hlo.txt files a manifest.json is written describing every
program: parameter layout (names/shapes in flat order), input/output
signatures and model hyperparameters. The Rust runtime loads programs and
addresses their flat argument lists through this manifest.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals):
    out = []
    for name, a in avals:
        out.append({"name": name, "shape": list(a.shape), "dtype": a.dtype.name})
    return out


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


class Emitter:
    """Lowers programs and accumulates manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.programs = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, key, fn, example_args, arg_names, kind, config, params_spec):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{key}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.programs[key] = {
            "file": fname,
            "kind": kind,
            "config": config,
            "params": [
                {"name": n, "shape": list(s)} for n, s in params_spec
            ],
            "inputs": _sig(list(zip(arg_names, example_args))),
        }
        print(f"  {fname:<44} {len(text)/1e6:.2f} MB hlo text")

    def finish(self):
        manifest = {"version": 1, "programs": self.programs}
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.programs)} programs)")


# --------------------------------------------------------------------------
# Program matrix
# --------------------------------------------------------------------------

def lstm_configs(full: bool):
    """Probability-model variants: paper config + fast/ablation configs."""
    cfgs = [
        # Default experiment config: 4-bit alphabet, 3×3 context, h64.
        M.LstmConfig(alphabet=16, seq=9, embed=64, hidden=64, batch=256),
        # 2-bit alphabet ablation.
        M.LstmConfig(alphabet=4, seq=9, embed=64, hidden=64, batch=256),
        # Context-size ablations: co-located only, and 5×5.
        M.LstmConfig(alphabet=16, seq=1, embed=64, hidden=64, batch=256),
        M.LstmConfig(alphabet=16, seq=25, embed=64, hidden=64, batch=256),
        # Tiny config for unit/integration tests (fast to compile+run).
        M.LstmConfig(alphabet=16, seq=9, embed=16, hidden=16, batch=32),
    ]
    if full:
        # The paper's exact hyperparameters (§IV): hidden 512 × 2 layers,
        # embedding 512, batch 256. Heavy on CPU; emitted for completeness.
        cfgs.append(M.LstmConfig(alphabet=16, seq=9, embed=512, hidden=512, batch=256))
    return cfgs


def lm_configs(full: bool):
    cfgs = [
        # ~70k params: figure-bench workload — small enough that a dozen
        # LSTM-coded checkpoints finish in minutes on CPU, large enough to
        # show the paper's curve shapes.
        M.LmConfig(tag="micro", vocab=256, dim=48, layers=2, heads=2, seq=48, batch=16),
        # ~0.9M params: default example workload.
        M.LmConfig(tag="tiny", vocab=512, dim=64, layers=2, heads=2, seq=64, batch=16),
        # ~6.5M params: the E2E example's "real small workload".
        M.LmConfig(tag="small", vocab=2048, dim=128, layers=4, heads=4, seq=128, batch=8),
    ]
    if full:
        # ~110M params — Pythia-410M-class structure, for completeness.
        cfgs.append(
            M.LmConfig(tag="base", vocab=16384, dim=768, layers=12, heads=12, seq=256, batch=4)
        )
    return cfgs


def vit_configs(full: bool):
    return [
        M.VitConfig(
            tag="tiny", patches=16, patch_dim=48, dim=64, layers=2, heads=2,
            classes=16, batch=32,
        )
    ]


def emit_lstm(e: Emitter, cfg: M.LstmConfig):
    spec = M.lstm_param_spec(cfg)
    pshapes = [_f32(s) for _, s in spec]
    pnames = [n for n, _ in spec]
    conf = {
        "alphabet": cfg.alphabet, "seq": cfg.seq, "embed": cfg.embed,
        "hidden": cfg.hidden, "layers": cfg.layers, "batch": cfg.batch,
        "lr": cfg.lr, "b1": cfg.b1, "b2": cfg.b2, "eps": cfg.eps,
    }
    tokens = _i32((cfg.batch, cfg.seq))
    targets = _i32((cfg.batch,))

    e.emit(
        f"{cfg.name}_probs", M.lstm_probs_fn(cfg), [*pshapes, tokens],
        [*pnames, "tokens"], "lstm_probs", conf, spec,
    )
    e.emit(
        f"{cfg.name}_train", M.lstm_train_fn(cfg),
        [*pshapes, *pshapes, *pshapes, _f32(()), tokens, targets],
        [*pnames, *[f"m.{n}" for n in pnames], *[f"v.{n}" for n in pnames],
         "step", "tokens", "targets"],
        "lstm_train", conf, spec,
    )
    e.emit(
        f"{cfg.name}_init", M.lstm_init_fn(cfg), [_i32(())], ["seed"],
        "lstm_init", conf, spec,
    )


def emit_lm(e: Emitter, cfg: M.LmConfig):
    spec = M.lm_param_spec(cfg)
    pshapes = [_f32(s) for _, s in spec]
    pnames = [n for n, _ in spec]
    conf = {
        "vocab": cfg.vocab, "dim": cfg.dim, "layers": cfg.layers,
        "heads": cfg.heads, "seq": cfg.seq, "batch": cfg.batch,
        "lr": cfg.lr, "b1": cfg.b1, "b2": cfg.b2, "eps": cfg.eps,
    }
    tokens = _i32((cfg.batch, cfg.seq + 1))
    e.emit(
        f"{cfg.name}_train", M.lm_train_fn(cfg),
        [*pshapes, *pshapes, *pshapes, _f32(()), tokens],
        [*pnames, *[f"m.{n}" for n in pnames], *[f"v.{n}" for n in pnames],
         "step", "tokens"],
        "lm_train", conf, spec,
    )
    e.emit(
        f"{cfg.name}_eval", M.lm_eval_fn(cfg), [*pshapes, tokens],
        [*pnames, "tokens"], "lm_eval", conf, spec,
    )
    e.emit(f"{cfg.name}_init", M.lm_init_fn(cfg), [_i32(())], ["seed"],
           "lm_init", conf, spec)


def emit_vit(e: Emitter, cfg: M.VitConfig):
    spec = M.vit_param_spec(cfg)
    pshapes = [_f32(s) for _, s in spec]
    pnames = [n for n, _ in spec]
    conf = {
        "patches": cfg.patches, "patch_dim": cfg.patch_dim, "dim": cfg.dim,
        "layers": cfg.layers, "heads": cfg.heads, "classes": cfg.classes,
        "batch": cfg.batch, "lr": cfg.lr, "b1": cfg.b1, "b2": cfg.b2,
        "eps": cfg.eps,
    }
    images = _f32((cfg.batch, cfg.patches, cfg.patch_dim))
    labels = _i32((cfg.batch,))
    e.emit(
        f"{cfg.name}_train", M.vit_train_fn(cfg),
        [*pshapes, *pshapes, *pshapes, _f32(()), images, labels],
        [*pnames, *[f"m.{n}" for n in pnames], *[f"v.{n}" for n in pnames],
         "step", "images", "labels"],
        "vit_train", conf, spec,
    )
    e.emit(f"{cfg.name}_init", M.vit_init_fn(cfg), [_i32(())], ["seed"],
           "vit_init", conf, spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also emit the paper-scale (h512) and lm_base programs")
    args = ap.parse_args()

    e = Emitter(args.out)
    for cfg in lstm_configs(args.full):
        emit_lstm(e, cfg)
    for cfg in lm_configs(args.full):
        emit_lm(e, cfg)
    for cfg in vit_configs(args.full):
        emit_vit(e, cfg)
    e.finish()


if __name__ == "__main__":
    main()

"""Layer-2 JAX models, AOT-lowered to HLO text by aot.py.

Three program families, all pure functions over *flat* parameter lists so
the Rust runtime can address them positionally (the order is published in
artifacts/manifest.json):

1. The paper's LSTM probability model (§III–IV): embedding → 2-layer LSTM
   (the Layer-1 Pallas fused cell) → linear head → softmax over the
   quantized-symbol alphabet. Two programs: `lstm_probs` (inference, feeds
   the arithmetic coder) and `lstm_train` (one Adam step on an observed
   batch — the online adaptation both encoder and decoder replay).
   Optimizer per §IV: Adam with β1 = 0, β2 = 0.9999, ε = 1e−5, lr = 1e−3.

2. A GPT-style causal LM — the Pythia-410M stand-in workload whose Adam
   checkpoints the experiments compress (DESIGN.md §3 substitutions).

3. A small ViT on pre-patchified synthetic images — the ViT-L32 stand-in.

The training-step programs take and return (params, m, v) so the Rust
trainer owns the complete Adam state — exactly the `{W_t, O_t}` checkpoint
content of paper Eq. 1.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import lstm_cell as lstm_kernel
from .kernels import ref as kref


# --------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, jnp-VJP backward.
# pallas_call has no transpose rule, so the train path rematerializes the
# cell with the pure-jnp reference inside the custom VJP.
# --------------------------------------------------------------------------

@jax.custom_vjp
def _cell(x, h, c, wx, wh, b):
    return lstm_kernel.lstm_cell(x, h, c, wx, wh, b)


def _cell_fwd(x, h, c, wx, wh, b):
    out = lstm_kernel.lstm_cell(x, h, c, wx, wh, b)
    return out, (x, h, c, wx, wh, b)


def _cell_bwd(saved, cotangent):
    _, vjp = jax.vjp(kref.lstm_cell_ref, *saved)
    return vjp(cotangent)


_cell.defvjp(_cell_fwd, _cell_bwd)


# --------------------------------------------------------------------------
# LSTM probability model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LstmConfig:
    """Shape/optimizer configuration of the probability model."""

    alphabet: int = 16       # 2^n quantization symbols
    seq: int = 9             # context length (3×3 window, paper Fig. 2)
    embed: int = 64
    hidden: int = 64
    layers: int = 2
    batch: int = 256         # paper §IV: batch size 256
    lr: float = 1e-3
    b1: float = 0.0          # paper §IV: "equivalent to RMSProp"
    b2: float = 0.9999
    eps: float = 1e-5

    @property
    def name(self) -> str:
        return f"lstm_a{self.alphabet}_s{self.seq}_h{self.hidden}_b{self.batch}"


def lstm_param_spec(cfg: LstmConfig):
    """Ordered (name, shape) list — the flat layout Rust mirrors."""
    spec = [("embed", (cfg.alphabet, cfg.embed))]
    for layer in range(cfg.layers):
        in_dim = cfg.embed if layer == 0 else cfg.hidden
        spec += [
            (f"l{layer}.wx", (in_dim, 4 * cfg.hidden)),
            (f"l{layer}.wh", (cfg.hidden, 4 * cfg.hidden)),
            (f"l{layer}.b", (4 * cfg.hidden,)),
        ]
    spec += [("head.w", (cfg.hidden, cfg.alphabet)), ("head.b", (cfg.alphabet,))]
    return spec


def _unflatten(spec, flat):
    assert len(spec) == len(flat), f"want {len(spec)} params, got {len(flat)}"
    return {name: arr for (name, _), arr in zip(spec, flat)}


def lstm_init_fn(cfg: LstmConfig):
    """seed:i32[] → flat params (deterministic truncated-normal-ish init)."""
    spec = lstm_param_spec(cfg)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        outs = []
        for i, (name, shape) in enumerate(spec):
            sub = jax.random.fold_in(key, i)
            if name.endswith(".b"):
                arr = jnp.zeros(shape, jnp.float32)
                if ".b" in name and name.startswith("l"):
                    # Forget-gate bias +1: standard LSTM trick, speeds up
                    # early online adaptation.
                    hidden = shape[0] // 4
                    arr = arr.at[hidden : 2 * hidden].set(1.0)
            else:
                fan_in = shape[0]
                arr = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(
                    jnp.float32(fan_in)
                )
            outs.append(arr)
        return tuple(outs)

    return init


def _lstm_hidden(params, tokens, cfg: LstmConfig, cell):
    """Shared LSTM trunk → final top-layer hidden state [B, H]."""
    emb = params["embed"][tokens]  # [B, S, E]
    batch = tokens.shape[0]
    hs = [jnp.zeros((batch, cfg.hidden), jnp.float32) for _ in range(cfg.layers)]
    cs = [jnp.zeros((batch, cfg.hidden), jnp.float32) for _ in range(cfg.layers)]
    for t in range(cfg.seq):  # static unroll; S ≤ 25
        inp = emb[:, t, :]
        for layer in range(cfg.layers):
            hs[layer], cs[layer] = cell(
                inp,
                hs[layer],
                cs[layer],
                params[f"l{layer}.wx"],
                params[f"l{layer}.wh"],
                params[f"l{layer}.b"],
            )
            inp = hs[layer]
    return hs[-1]


def lstm_probs_fn(cfg: LstmConfig):
    """(params…, tokens:i32[B,S]) → probs:f32[B,A] (softmax)."""
    spec = lstm_param_spec(cfg)

    def probs(*args):
        flat, tokens = args[:-1], args[-1]
        params = _unflatten(spec, flat)
        h = _lstm_hidden(params, tokens, cfg, _cell)
        logits = h @ params["head.w"] + params["head.b"]
        return (jax.nn.softmax(logits, axis=-1),)

    return probs


def lstm_loss(params, tokens, targets, cfg: LstmConfig, cell):
    """Mean cross-entropy of the next-symbol prediction."""
    h = _lstm_hidden(params, tokens, cfg, cell)
    logits = h @ params["head.w"] + params["head.b"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def lstm_train_fn(cfg: LstmConfig):
    """(params…, m…, v…, step:f32[], tokens, targets) → (params'…, m'…, v'…, loss).

    One Adam step with the paper's hyperparameters. The backward pass goes
    through the jnp reference cell (custom VJP above).
    """
    spec = lstm_param_spec(cfg)
    n = len(spec)

    def train(*args):
        flat = args[:n]
        m = args[n : 2 * n]
        v = args[2 * n : 3 * n]
        step, tokens, targets = args[3 * n], args[3 * n + 1], args[3 * n + 2]

        def loss_of(flat_params):
            return lstm_loss(_unflatten(spec, flat_params), tokens, targets, cfg, _cell)

        loss, grads = jax.value_and_grad(loss_of)(flat)
        new_p, new_m, new_v = adam_step(
            flat, grads, m, v, step, cfg.lr, cfg.b1, cfg.b2, cfg.eps
        )
        return (*new_p, *new_m, *new_v, loss)

    return train


# --------------------------------------------------------------------------
# Shared Adam
# --------------------------------------------------------------------------

def adam_step(params, grads, m, v, step, lr, b1, b2, eps):
    """Flat-list Adam with bias correction. `step` is the 1-based f32 step."""
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# --------------------------------------------------------------------------
# GPT-style causal LM (Pythia stand-in)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LmConfig:
    """Decoder-only transformer configuration."""

    tag: str = "tiny"
    vocab: int = 512
    dim: int = 64
    layers: int = 2
    heads: int = 2
    seq: int = 64            # context length (training window)
    batch: int = 16
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    @property
    def name(self) -> str:
        return f"lm_{self.tag}"


def _block_spec(prefix, dim):
    return [
        (f"{prefix}.ln1.g", (dim,)),
        (f"{prefix}.ln1.b", (dim,)),
        (f"{prefix}.attn.wqkv", (dim, 3 * dim)),
        (f"{prefix}.attn.wo", (dim, dim)),
        (f"{prefix}.ln2.g", (dim,)),
        (f"{prefix}.ln2.b", (dim,)),
        (f"{prefix}.mlp.w1", (dim, 4 * dim)),
        (f"{prefix}.mlp.b1", (4 * dim,)),
        (f"{prefix}.mlp.w2", (4 * dim, dim)),
        (f"{prefix}.mlp.b2", (dim,)),
    ]


def lm_param_spec(cfg: LmConfig):
    spec = [("tok_embed", (cfg.vocab, cfg.dim)), ("pos_embed", (cfg.seq, cfg.dim))]
    for i in range(cfg.layers):
        spec += _block_spec(f"h{i}", cfg.dim)
    spec += [("ln_f.g", (cfg.dim,)), ("ln_f.b", (cfg.dim,))]
    return spec


def lm_init_fn(cfg: LmConfig):
    spec = lm_param_spec(cfg)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        outs = []
        for i, (name, shape) in enumerate(spec):
            sub = jax.random.fold_in(key, i)
            if name.endswith((".b", ".b1", ".b2")) or name == "ln_f.b":
                arr = jnp.zeros(shape, jnp.float32)
            elif name.endswith(".g"):
                arr = jnp.ones(shape, jnp.float32)
            else:
                scale = 0.02
                arr = scale * jax.random.normal(sub, shape, jnp.float32)
            outs.append(arr)
        return tuple(outs)

    return init


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _causal_attn(x, wqkv, wo, heads):
    batch, seq, dim = x.shape
    hd = dim // heads
    qkv = x @ wqkv  # [B,T,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(t):
        return t.reshape(batch, seq, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(batch, seq, dim)
    return out @ wo


def _lm_logits(params, tokens_in, cfg: LmConfig):
    x = params["tok_embed"][tokens_in] + params["pos_embed"][None, : tokens_in.shape[1]]
    for i in range(cfg.layers):
        p = f"h{i}"
        a = _layer_norm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        x = x + _causal_attn(a, params[f"{p}.attn.wqkv"], params[f"{p}.attn.wo"], cfg.heads)
        h = _layer_norm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        h = jax.nn.gelu(h @ params[f"{p}.mlp.w1"] + params[f"{p}.mlp.b1"])
        x = x + h @ params[f"{p}.mlp.w2"] + params[f"{p}.mlp.b2"]
    x = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["tok_embed"].T  # tied output head


def lm_loss(params, tokens, cfg: LmConfig):
    """tokens: i32[B, seq+1]; next-token cross-entropy."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = _lm_logits(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def lm_train_fn(cfg: LmConfig):
    """(params…, m…, v…, step:f32[], tokens:i32[B,seq+1]) → (…, loss)."""
    spec = lm_param_spec(cfg)
    n = len(spec)

    def train(*args):
        flat = args[:n]
        m = args[n : 2 * n]
        v = args[2 * n : 3 * n]
        step, tokens = args[3 * n], args[3 * n + 1]

        def loss_of(flat_params):
            return lm_loss(_unflatten(spec, flat_params), tokens, cfg)

        loss, grads = jax.value_and_grad(loss_of)(flat)
        new_p, new_m, new_v = adam_step(
            flat, grads, m, v, step, cfg.lr, cfg.b1, cfg.b2, cfg.eps
        )
        return (*new_p, *new_m, *new_v, loss)

    return train


def lm_eval_fn(cfg: LmConfig):
    """(params…, tokens) → (loss,) — held-out loss for resume experiments."""
    spec = lm_param_spec(cfg)
    n = len(spec)

    def ev(*args):
        flat, tokens = args[:n], args[n]
        return (lm_loss(_unflatten(spec, flat), tokens, cfg),)

    return ev


# --------------------------------------------------------------------------
# Small ViT (ViT-L32 stand-in) on pre-patchified synthetic images
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class VitConfig:
    tag: str = "tiny"
    patches: int = 16        # tokens per image (e.g. 4×4 grid)
    patch_dim: int = 48      # flattened patch size (e.g. 4×4×3)
    dim: int = 64
    layers: int = 2
    heads: int = 2
    classes: int = 16
    batch: int = 32
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    @property
    def name(self) -> str:
        return f"vit_{self.tag}"


def vit_param_spec(cfg: VitConfig):
    spec = [
        ("patch.w", (cfg.patch_dim, cfg.dim)),
        ("patch.b", (cfg.dim,)),
        ("pos_embed", (cfg.patches, cfg.dim)),
    ]
    for i in range(cfg.layers):
        spec += _block_spec(f"h{i}", cfg.dim)
    spec += [
        ("ln_f.g", (cfg.dim,)),
        ("ln_f.b", (cfg.dim,)),
        ("head.w", (cfg.dim, cfg.classes)),
        ("head.b", (cfg.classes,)),
    ]
    return spec


def vit_init_fn(cfg: VitConfig):
    spec = vit_param_spec(cfg)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        outs = []
        for i, (name, shape) in enumerate(spec):
            sub = jax.random.fold_in(key, i)
            if name.endswith((".b", ".b1", ".b2")):
                arr = jnp.zeros(shape, jnp.float32)
            elif name.endswith(".g"):
                arr = jnp.ones(shape, jnp.float32)
            else:
                arr = 0.02 * jax.random.normal(sub, shape, jnp.float32)
            outs.append(arr)
        return tuple(outs)

    return init


def _bidir_attn(x, wqkv, wo, heads):
    batch, seq, dim = x.shape
    hd = dim // heads
    q, k, v = jnp.split(x @ wqkv, 3, axis=-1)

    def split_heads(t):
        return t.reshape(batch, seq, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    att = jax.nn.softmax((q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd)), axis=-1)
    return (att @ v).transpose(0, 2, 1, 3).reshape(batch, seq, dim) @ wo


def vit_loss(params, images, labels, cfg: VitConfig):
    """images: f32[B, patches, patch_dim]; labels: i32[B]."""
    x = images @ params["patch.w"] + params["patch.b"] + params["pos_embed"][None]
    for i in range(cfg.layers):
        p = f"h{i}"
        a = _layer_norm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        x = x + _bidir_attn(a, params[f"{p}.attn.wqkv"], params[f"{p}.attn.wo"], cfg.heads)
        h = _layer_norm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        h = jax.nn.gelu(h @ params[f"{p}.mlp.w1"] + params[f"{p}.mlp.b1"])
        x = x + h @ params[f"{p}.mlp.w2"] + params[f"{p}.mlp.b2"]
    x = _layer_norm(x.mean(axis=1), params["ln_f.g"], params["ln_f.b"])
    logits = x @ params["head.w"] + params["head.b"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def vit_train_fn(cfg: VitConfig):
    """(params…, m…, v…, step, images, labels) → (…, loss)."""
    spec = vit_param_spec(cfg)
    n = len(spec)

    def train(*args):
        flat = args[:n]
        m = args[n : 2 * n]
        v = args[2 * n : 3 * n]
        step, images, labels = args[3 * n], args[3 * n + 1], args[3 * n + 2]

        def loss_of(flat_params):
            return vit_loss(_unflatten(spec, flat_params), images, labels, cfg)

        loss, grads = jax.value_and_grad(loss_of)(flat)
        new_p, new_m, new_v = adam_step(
            flat, grads, m, v, step, cfg.lr, cfg.b1, cfg.b2, cfg.eps
        )
        return (*new_p, *new_m, *new_v, loss)

    return train
